//! Multi-GPU load balancing (§6.1.1 — the dissertation's first future-work
//! direction, implemented here as an extension).
//!
//! The insight transfers directly: a multi-GPU GEMM is the same
//! quantization problem one level up.  Splitting *tiles* across devices
//! re-introduces wave quantization per device; splitting the aggregate
//! *MAC-iteration space* evenly across the device pool (device-level
//! Stream-K) keeps every GPU busy within one iteration share, at the cost
//! of inter-device fixup for boundary tiles (which crosses NVLink/PCIe and
//! is charged accordingly).

use super::{decomp, Blocking, Decomposition, GemmShape};
use crate::sim::gpu::{GpuSpec, Precision};
use crate::sim::CostModel;

/// How work is divided among devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MultiGpuPolicy {
    /// Contiguous tile ranges per device (tile-split): each device gets
    /// `ceil(tiles / n)` tiles — quantizes badly when tiles ~ n * p.
    TileSplit,
    /// Device-level Stream-K: the aggregate iteration space is split
    /// evenly (within one) across devices; boundary tiles incur an
    /// inter-device reduction.
    IterSplit,
}

/// Outcome of a multi-GPU schedule.
#[derive(Debug, Clone)]
pub struct MultiGpuSim {
    pub makespan: f64,
    pub per_device: Vec<f64>,
    /// MAC iterations assigned to each device — sums to `tiles *
    /// iters_per_tile` under either policy (the conservation invariant
    /// the tests pin).
    pub per_device_iters: Vec<u64>,
    /// Tiles whose partials cross a device boundary (IterSplit only).
    pub boundary_tiles: usize,
}

/// Simulate an `n_gpus`-device GEMM under a policy.  `interconnect_us` is
/// the one-way cost of moving one output tile's partials between devices
/// (NVLink-class ~3 us for a 64 KiB tile).
pub fn simulate_multi_gpu(
    shape: GemmShape,
    blk: Blocking,
    model: &CostModel,
    gpu: &GpuSpec,
    prec: Precision,
    n_gpus: usize,
    policy: MultiGpuPolicy,
    interconnect_us: f64,
) -> MultiGpuSim {
    let n = n_gpus.max(1);
    let tiles = blk.tiles(shape);
    let ipt = blk.iters_per_tile(shape);
    let _ = prec;

    match policy {
        MultiGpuPolicy::TileSplit => {
            // Device d gets a contiguous chunk of tiles; within a device,
            // the best single-GPU schedule (two-tile hybrid / model grid).
            let per = tiles.div_ceil(n);
            let mut per_device = Vec::with_capacity(n);
            let mut per_device_iters = Vec::with_capacity(n);
            for d in 0..n {
                let start = d * per;
                let end = ((d + 1) * per).min(tiles);
                if start >= end {
                    per_device.push(0.0);
                    per_device_iters.push(0);
                    continue;
                }
                let dev_tiles = end - start;
                per_device_iters.push(dev_tiles as u64 * ipt);
                // Shape covering exactly dev_tiles (1-D tiling along m).
                let sub = GemmShape::new(dev_tiles * blk.bm, blk.bn, shape.k);
                let d_plan = if dev_tiles > gpu.sms {
                    Decomposition::HybridTwoTile { p: gpu.sms }
                } else {
                    Decomposition::StreamK {
                        g: super::best_grid(sub, blk, gpu.sms, model).max(dev_tiles.min(gpu.sms)),
                    }
                };
                let plan = decomp::plan(sub, blk, d_plan);
                let t = crate::exec::gemm::simulate_plan(&plan, model, gpu, prec).makespan;
                let dp = crate::exec::gemm::simulate_plan(
                    &decomp::plan(sub, blk, Decomposition::DataParallel),
                    model,
                    gpu,
                    prec,
                )
                .makespan;
                per_device.push(t.min(dp));
            }
            MultiGpuSim {
                makespan: per_device.iter().cloned().fold(0.0, f64::max),
                per_device,
                per_device_iters,
                boundary_tiles: 0,
            }
        }
        MultiGpuPolicy::IterSplit => {
            // Aggregate iterations split evenly (within one) over devices;
            // each device runs its share through its own Stream-K.  Tiles
            // straddling a device boundary pay one interconnect fixup.
            let total = tiles as u64 * ipt;
            let per = total / n as u64;
            let rem = total % n as u64;
            let mut per_device = Vec::with_capacity(n);
            let mut per_device_iters = Vec::with_capacity(n);
            let mut boundary_tiles = 0usize;
            let mut cursor = 0u64;
            for d in 0..n {
                let share = per + if (d as u64) < rem { 1 } else { 0 };
                let start = cursor;
                let end = cursor + share;
                cursor = end;
                per_device_iters.push(share);
                if share == 0 {
                    per_device.push(0.0);
                    continue;
                }
                // Device-local iteration share expressed as an equivalent
                // single-device problem with the same iteration count.
                let dev_tiles = (end.div_ceil(ipt) - start / ipt) as usize;
                let crosses_start = start % ipt != 0;
                let crosses_end = end % ipt != 0 && end < total;
                boundary_tiles += crosses_start as usize + crosses_end as usize;
                let sub = GemmShape::new(dev_tiles * blk.bm, blk.bn, shape.k);
                let d_plan = if dev_tiles > gpu.sms {
                    Decomposition::HybridTwoTile { p: gpu.sms }
                } else {
                    Decomposition::StreamK {
                        g: super::best_grid(sub, blk, gpu.sms, model).max(dev_tiles.min(gpu.sms)),
                    }
                };
                let plan = decomp::plan(sub, blk, d_plan);
                // Scale the makespan to the actual share (the equivalent
                // problem rounds up to whole tiles).
                let t = crate::exec::gemm::simulate_plan(&plan, model, gpu, prec).makespan;
                let scale = share as f64 / (dev_tiles as u64 * ipt).max(1) as f64;
                let fixup = (crosses_start as usize + crosses_end as usize) as f64
                    * interconnect_us
                    * 1e-6;
                per_device.push(t * scale + fixup);
            }
            MultiGpuSim {
                makespan: per_device.iter().cloned().fold(0.0, f64::max),
                per_device,
                per_device_iters,
                boundary_tiles,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::vendor_gemm;

    fn setup() -> (GpuSpec, Blocking, CostModel) {
        let gpu = GpuSpec::a100();
        let blk = Blocking::paper_default(Precision::F16F32);
        let model = vendor_gemm::member_cost_model(&gpu, blk, Precision::F16F32);
        (gpu, blk, model)
    }

    #[test]
    fn iter_split_wins_on_deep_k_few_tiles() {
        // The device-level quantization failure for tile-split: fewer
        // tiles than devices.  One device gets everything, three idle.
        // Iter-split spreads the k-dimension across the pool.
        let (gpu, blk, model) = setup();
        let shape = GemmShape::new(256, 128, 1 << 16); // 2 tiles, deep k
        assert_eq!(blk.tiles(shape), 2);
        let ts = simulate_multi_gpu(
            shape, blk, &model, &gpu, Precision::F16F32, 4,
            MultiGpuPolicy::TileSplit, 3.0,
        );
        let is = simulate_multi_gpu(
            shape, blk, &model, &gpu, Precision::F16F32, 4,
            MultiGpuPolicy::IterSplit, 3.0,
        );
        assert!(
            is.makespan < ts.makespan * 0.7,
            "iter-split {} vs tile-split {}",
            is.makespan,
            ts.makespan
        );
    }

    #[test]
    fn single_gpu_policies_agree() {
        let (gpu, blk, model) = setup();
        let shape = GemmShape::new(2048, 2048, 2048);
        let a = simulate_multi_gpu(
            shape, blk, &model, &gpu, Precision::F16F32, 1,
            MultiGpuPolicy::TileSplit, 3.0,
        );
        let b = simulate_multi_gpu(
            shape, blk, &model, &gpu, Precision::F16F32, 1,
            MultiGpuPolicy::IterSplit, 3.0,
        );
        assert!((a.makespan - b.makespan).abs() / a.makespan < 0.05);
        assert_eq!(b.boundary_tiles, 0);
    }

    #[test]
    fn scaling_with_device_count() {
        let (gpu, blk, model) = setup();
        let shape = GemmShape::new(8192, 8192, 4096);
        let t1 = simulate_multi_gpu(
            shape, blk, &model, &gpu, Precision::F16F32, 1,
            MultiGpuPolicy::IterSplit, 3.0,
        )
        .makespan;
        let t4 = simulate_multi_gpu(
            shape, blk, &model, &gpu, Precision::F16F32, 4,
            MultiGpuPolicy::IterSplit, 3.0,
        )
        .makespan;
        let speedup = t1 / t4;
        assert!(speedup > 2.8 && speedup <= 4.2, "4-GPU speedup {speedup}");
    }

    #[test]
    fn iter_split_never_worse_than_tile_split_beyond_fixup() {
        // The §6.1.1 invariant: device-level Stream-K balances iterations
        // within one, so its makespan can exceed tile-split's only by the
        // interconnect fixup (plus sub-problem rounding slack) — never by
        // a quantization cliff.
        let (gpu, blk, model) = setup();
        let interconnect_us = 3.0;
        for shape in [
            GemmShape::new(256, 128, 1 << 16),
            GemmShape::new(1000, 1000, 1000),
            GemmShape::new(2048, 2048, 2048),
            GemmShape::new(8192, 8192, 4096),
        ] {
            for n in [2usize, 3, 4, 8] {
                let ts = simulate_multi_gpu(
                    shape, blk, &model, &gpu, Precision::F16F32, n,
                    MultiGpuPolicy::TileSplit, interconnect_us,
                );
                let is = simulate_multi_gpu(
                    shape, blk, &model, &gpu, Precision::F16F32, n,
                    MultiGpuPolicy::IterSplit, interconnect_us,
                );
                let fixup_slack = 2.0 * interconnect_us * 1e-6;
                assert!(
                    is.makespan <= ts.makespan * 1.10 + fixup_slack,
                    "{shape:?} x{n}: iter-split {} vs tile-split {}",
                    is.makespan,
                    ts.makespan
                );
            }
        }
    }

    #[test]
    fn per_device_iterations_conserve_the_total() {
        // Neither policy may drop or duplicate MAC iterations, whatever
        // the device count does to quantization.
        let (gpu, blk, model) = setup();
        for shape in [
            GemmShape::new(256, 128, 1 << 16),
            GemmShape::new(1000, 1000, 1000),
            GemmShape::new(2048, 2048, 2048),
        ] {
            let total = blk.tiles(shape) as u64 * blk.iters_per_tile(shape);
            for n in [1usize, 2, 3, 4, 8] {
                for policy in [MultiGpuPolicy::TileSplit, MultiGpuPolicy::IterSplit] {
                    let r = simulate_multi_gpu(
                        shape, blk, &model, &gpu, Precision::F16F32, n, policy, 3.0,
                    );
                    assert_eq!(r.per_device_iters.len(), n);
                    assert_eq!(
                        r.per_device_iters.iter().sum::<u64>(),
                        total,
                        "{shape:?} x{n} {policy:?}"
                    );
                    // Iter-split balances within one iteration.
                    if policy == MultiGpuPolicy::IterSplit {
                        let lo = r.per_device_iters.iter().min().unwrap();
                        let hi = r.per_device_iters.iter().max().unwrap();
                        assert!(hi - lo <= 1, "{shape:?} x{n}: {lo}..{hi}");
                    }
                }
            }
        }
    }

    #[test]
    fn boundary_tiles_bounded_by_internal_cuts() {
        // n devices make n-1 cuts in the iteration space; each cut can
        // split at most one tile, charged once on each side — so at most
        // 2(n-1) boundary crossings, and zero when every cut lands on a
        // tile boundary.
        let (gpu, blk, model) = setup();
        let shape = GemmShape::new(1000, 1000, 1000);
        for n in [2usize, 3, 4, 8] {
            let r = simulate_multi_gpu(
                shape, blk, &model, &gpu, Precision::F16F32, n,
                MultiGpuPolicy::IterSplit, 3.0,
            );
            assert!(
                r.boundary_tiles <= 2 * (n - 1),
                "{} > {}",
                r.boundary_tiles,
                2 * (n - 1)
            );
        }
        // Tiles divisible by devices and no remainder: cuts align, no
        // cross-device fixups.
        let aligned = GemmShape::new(2048, 2048, 2048);
        let tiles = blk.tiles(aligned);
        assert_eq!(tiles % 4, 0);
        let r = simulate_multi_gpu(
            aligned, blk, &model, &gpu, Precision::F16F32, 4,
            MultiGpuPolicy::IterSplit, 3.0,
        );
        assert_eq!(r.boundary_tiles, 0);
    }

    #[test]
    fn boundary_tiles_bounded_by_device_count() {
        let (gpu, blk, model) = setup();
        let shape = GemmShape::new(1000, 1000, 1000);
        for n in [2usize, 4, 8] {
            let r = simulate_multi_gpu(
                shape, blk, &model, &gpu, Precision::F16F32, n,
                MultiGpuPolicy::IterSplit, 3.0,
            );
            assert!(r.boundary_tiles <= 2 * n, "{} > {}", r.boundary_tiles, 2 * n);
        }
    }
}

//! Work decomposition strategies (§5.2) as explicit per-CTA iteration plans.
//!
//! Every strategy produces a [`Plan`]: for each CTA, the list of
//! `(tile, local iteration range)` it executes, in order.  The plan is what
//! both the executor (real numerics through the PJRT MacLoop artifacts) and
//! the simulator (cost model + block scheduler) consume — one source of
//! truth for "who computes what".

use super::{Blocking, GemmShape};

/// A CTA's contiguous run of MAC-loop iterations within one output tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileRange {
    pub tile: usize,
    /// Local iteration range within the tile: `[iter_begin, iter_end)`,
    /// `iter_end <= iters_per_tile`.
    pub iter_begin: u64,
    pub iter_end: u64,
}

impl TileRange {
    pub fn iters(&self) -> u64 {
        self.iter_end - self.iter_begin
    }

    /// Does this range start the tile (k=0)?  The starting CTA owns the
    /// output and accumulates peers' partials (Algorithm 10).
    pub fn starts_tile(&self) -> bool {
        self.iter_begin == 0
    }
}

/// One CTA's full workload.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CtaPlan {
    pub ranges: Vec<TileRange>,
}

impl CtaPlan {
    pub fn iters(&self) -> u64 {
        self.ranges.iter().map(TileRange::iters).sum()
    }
}

/// The decomposition strategies of §5.2 (+ §5.3.2 hybrids).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decomposition {
    /// §5.2.2 — one CTA per output tile.
    DataParallel,
    /// §5.2.3 — each tile split across `s` CTAs along k.
    FixedSplit { s: usize },
    /// §5.2.4 / Algorithm 10 — even iteration share over `g` CTAs.
    StreamK { g: usize },
    /// §5.3.2 — "data-parallel + one-tile Stream-K": full DP waves, the
    /// final partial wave's tiles iteration-balanced over `p` CTAs.
    HybridOneTile { p: usize },
    /// §5.3.2 — "two-tile Stream-K + data-parallel": one fewer DP wave;
    /// each Stream-K CTA gets one-to-two tiles' worth of iterations.
    HybridTwoTile { p: usize },
}

impl Decomposition {
    pub fn name(self) -> &'static str {
        match self {
            Decomposition::DataParallel => "data-parallel",
            Decomposition::FixedSplit { .. } => "fixed-split",
            Decomposition::StreamK { .. } => "stream-k",
            Decomposition::HybridOneTile { .. } => "dp+one-tile-sk",
            Decomposition::HybridTwoTile { .. } => "two-tile-sk+dp",
        }
    }
}

/// A full decomposition plan for one GEMM launch.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub shape: GemmShape,
    pub blocking: Blocking,
    pub decomposition: Decomposition,
    pub ctas: Vec<CtaPlan>,
    pub num_tiles: usize,
    pub iters_per_tile: u64,
}

impl Plan {
    /// CTAs covering each tile (FixupPeers per tile).
    pub fn peers_per_tile(&self) -> Vec<u32> {
        let mut peers = vec![0u32; self.num_tiles];
        for cta in &self.ctas {
            for r in &cta.ranges {
                peers[r.tile] += 1;
            }
        }
        peers
    }

    /// Validate: every tile's iterations covered exactly once.
    pub fn validate(&self) -> crate::Result<()> {
        use anyhow::ensure;
        let mut covered = vec![0u64; self.num_tiles];
        for cta in &self.ctas {
            for r in &cta.ranges {
                ensure!(r.tile < self.num_tiles, "tile {} oob", r.tile);
                ensure!(
                    r.iter_begin < r.iter_end && r.iter_end <= self.iters_per_tile,
                    "bad range {r:?}"
                );
                covered[r.tile] += r.iters();
            }
        }
        for (t, &c) in covered.iter().enumerate() {
            ensure!(
                c == self.iters_per_tile,
                "tile {t}: covered {c} of {} iters",
                self.iters_per_tile
            );
        }
        // Ranges within a tile must not overlap: since totals match and all
        // ranges are sub-intervals, verify pairwise disjointness per tile.
        let mut by_tile: Vec<Vec<(u64, u64)>> = vec![Vec::new(); self.num_tiles];
        for cta in &self.ctas {
            for r in &cta.ranges {
                by_tile[r.tile].push((r.iter_begin, r.iter_end));
            }
        }
        for (t, ranges) in by_tile.iter_mut().enumerate() {
            ranges.sort_unstable();
            for w in ranges.windows(2) {
                ensure!(
                    w[0].1 <= w[1].0,
                    "tile {t}: overlapping ranges {w:?}"
                );
            }
        }
        Ok(())
    }

    /// Max absolute difference in iterations across CTAs (Stream-K's
    /// headline guarantee: <= 1 for the basic decomposition).
    pub fn iter_imbalance(&self) -> u64 {
        let iters: Vec<u64> = self.ctas.iter().map(CtaPlan::iters).collect();
        let max = iters.iter().copied().max().unwrap_or(0);
        let min = iters.iter().copied().min().unwrap_or(0);
        max - min
    }
}

/// Build the plan for a decomposition.
pub fn plan(shape: GemmShape, blocking: Blocking, decomposition: Decomposition) -> Plan {
    let num_tiles = blocking.tiles(shape);
    let iters_per_tile = blocking.iters_per_tile(shape);
    let ctas = match decomposition {
        Decomposition::DataParallel => plan_dp(num_tiles, iters_per_tile),
        Decomposition::FixedSplit { s } => plan_fixed_split(num_tiles, iters_per_tile, s),
        Decomposition::StreamK { g } => plan_stream_k(num_tiles, iters_per_tile, g, 0),
        Decomposition::HybridOneTile { p } => plan_hybrid(num_tiles, iters_per_tile, p, false),
        Decomposition::HybridTwoTile { p } => plan_hybrid(num_tiles, iters_per_tile, p, true),
    };
    Plan {
        shape,
        blocking,
        decomposition,
        ctas,
        num_tiles,
        iters_per_tile,
    }
}

fn plan_dp(tiles: usize, ipt: u64) -> Vec<CtaPlan> {
    (0..tiles)
        .map(|t| CtaPlan {
            ranges: vec![TileRange {
                tile: t,
                iter_begin: 0,
                iter_end: ipt,
            }],
        })
        .collect()
}

fn plan_fixed_split(tiles: usize, ipt: u64, s: usize) -> Vec<CtaPlan> {
    let s = s.max(1) as u64;
    let per = ipt.div_ceil(s);
    let mut ctas = Vec::new();
    // CTA (x, y): tile x, split y — matches Algorithm 9's fork order.
    for y in 0..s {
        for t in 0..tiles {
            let begin = y * per;
            let end = ((y + 1) * per).min(ipt);
            if begin < end {
                ctas.push(CtaPlan {
                    ranges: vec![TileRange {
                        tile: t,
                        iter_begin: begin,
                        iter_end: end,
                    }],
                });
            }
        }
    }
    ctas
}

/// Basic Stream-K over `g` CTAs covering tiles `[tile_base, tile_base + tiles)`.
fn plan_stream_k(tiles: usize, ipt: u64, g: usize, tile_base: usize) -> Vec<CtaPlan> {
    let g = g.max(1) as u64;
    let total = tiles as u64 * ipt;
    if total == 0 {
        return Vec::new();
    }
    // Even share within one: first `rem` CTAs take `per + 1`.
    let per = total / g;
    let rem = total % g;
    let mut ctas = Vec::new();
    let mut iter = 0u64;
    for x in 0..g {
        let share = per + if x < rem { 1 } else { 0 };
        if share == 0 {
            continue;
        }
        let iter_end_cta = iter + share;
        let mut ranges = Vec::new();
        let mut cur = iter;
        while cur < iter_end_cta {
            let tile = (cur / ipt) as usize;
            let tile_start = tile as u64 * ipt;
            let local_begin = cur - tile_start;
            let local_end = (iter_end_cta - tile_start).min(ipt);
            ranges.push(TileRange {
                tile: tile + tile_base,
                iter_begin: local_begin,
                iter_end: local_end,
            });
            cur = tile_start + local_end;
        }
        ctas.push(CtaPlan { ranges });
        iter = iter_end_cta;
    }
    ctas
}

/// Hybrid schedules (§5.3.2).  `two_tile` selects the "two-tile Stream-K +
/// data-parallel" variant; otherwise "data-parallel + one-tile Stream-K".
fn plan_hybrid(tiles: usize, ipt: u64, p: usize, two_tile: bool) -> Vec<CtaPlan> {
    let p = p.max(1);
    let full_waves = tiles / p;
    if tiles % p == 0 {
        // Perfect quantization: pure data-parallel is optimal (Stream-K
        // generalizes to DP here, §5.2.4).
        return plan_dp(tiles, ipt);
    }
    // Waves to run data-parallel; the rest is the Stream-K region.
    let dp_waves = if two_tile {
        full_waves.saturating_sub(1)
    } else {
        full_waves
    };
    let dp_tiles = dp_waves * p;
    let sk_tiles = tiles - dp_tiles;

    // Stream-K region first (tiles [0, sk_tiles)), then full DP waves — the
    // skewed region runs while DP waves fill the machine behind it.
    let sk_iters = sk_tiles as u64 * ipt;
    let g = p.min(sk_iters.max(1) as usize);
    let mut ctas = plan_stream_k(sk_tiles, ipt, g, 0);
    for t in sk_tiles..tiles {
        ctas.push(CtaPlan {
            ranges: vec![TileRange {
                tile: t,
                iter_begin: 0,
                iter_end: ipt,
            }],
        });
    }
    ctas
}

#[cfg(test)]
mod tests {
    use super::*;

    const SHAPE: GemmShape = GemmShape {
        m: 384,
        n: 384,
        k: 128,
    };
    const BLK: Blocking = Blocking::new(128, 128, 4);

    #[test]
    fn dp_one_cta_per_tile() {
        let p = plan(SHAPE, BLK, Decomposition::DataParallel);
        assert_eq!(p.ctas.len(), 9);
        p.validate().unwrap();
        assert_eq!(p.iter_imbalance(), 0);
        assert!(p.peers_per_tile().iter().all(|&x| x == 1));
    }

    #[test]
    fn fixed_split_splits_every_tile() {
        let p = plan(SHAPE, BLK, Decomposition::FixedSplit { s: 4 });
        assert_eq!(p.ctas.len(), 36);
        p.validate().unwrap();
        assert!(p.peers_per_tile().iter().all(|&x| x == 4));
    }

    #[test]
    fn fixed_split_s1_equals_dp() {
        // "it functions identically to the data-parallel decomposition when
        // the splitting factor s = 1" (§5.2.3).
        let a = plan(SHAPE, BLK, Decomposition::FixedSplit { s: 1 });
        let b = plan(SHAPE, BLK, Decomposition::DataParallel);
        assert_eq!(a.ctas, b.ctas);
    }

    #[test]
    fn stream_k_even_share_within_one() {
        // The worked §5.2.4 example: g=4 CTAs, 9 tiles x 32 iters = 288
        // iterations => exactly 72 per CTA (100% quantization).
        let p = plan(SHAPE, BLK, Decomposition::StreamK { g: 4 });
        assert_eq!(p.ctas.len(), 4);
        p.validate().unwrap();
        for cta in &p.ctas {
            assert_eq!(cta.iters(), 72);
        }
        assert_eq!(p.iter_imbalance(), 0);
    }

    #[test]
    fn stream_k_generalizes_to_dp() {
        // "when g equals the number of output tiles, Stream-K behaves
        // identically to the data-parallel decomposition" (§5.2.4).
        let p = plan(SHAPE, BLK, Decomposition::StreamK { g: 9 });
        let dp = plan(SHAPE, BLK, Decomposition::DataParallel);
        assert_eq!(p.ctas, dp.ctas);
    }

    #[test]
    fn stream_k_generalizes_to_fixed_split() {
        // "When the grid size g is an even multiple of the number of output
        // tiles, Stream-K functions exactly as the fixed-split
        // decomposition" — iterations per CTA match (CTA *ordering*
        // differs: fixed-split forks (x, y) tile-major).
        let sk = plan(SHAPE, BLK, Decomposition::StreamK { g: 18 });
        let fs = plan(SHAPE, BLK, Decomposition::FixedSplit { s: 2 });
        sk.validate().unwrap();
        fs.validate().unwrap();
        assert_eq!(sk.ctas.len(), fs.ctas.len());
        assert!(sk.ctas.iter().all(|c| c.iters() == 16));
        assert!(fs.ctas.iter().all(|c| c.iters() == 16));
        assert!(sk.peers_per_tile().iter().all(|&x| x == 2));
    }

    #[test]
    fn stream_k_imbalance_at_most_one() {
        for (m, n, k) in [(300, 500, 700), (128, 128, 8192), (1000, 1000, 96)] {
            let s = GemmShape::new(m, n, k);
            let blk = Blocking::new(128, 128, 32);
            let p = plan(s, blk, Decomposition::StreamK { g: 108 });
            p.validate().unwrap();
            assert!(p.iter_imbalance() <= 1, "imbalance {}", p.iter_imbalance());
        }
    }

    #[test]
    fn hybrid_two_tile_structure() {
        // Fig 5.3c: 896x384x128 => 21 tiles on p=4: 4 full DP waves + 5
        // tiles stream-k'd... two-tile: w = floor(21/4) = 5, dp_waves = 4,
        // sk tiles = 21 - 16 = 5 over 4 CTAs (1.25 tiles each).
        let s = GemmShape::new(896, 384, 128);
        let p = plan(s, BLK, Decomposition::HybridTwoTile { p: 4 });
        assert_eq!(p.num_tiles, 21);
        p.validate().unwrap();
        // 4 SK CTAs + 16 DP CTAs.
        assert_eq!(p.ctas.len(), 20);
        let sk_iters: Vec<u64> = p.ctas[..4].iter().map(CtaPlan::iters).collect();
        for &i in &sk_iters {
            // 5 tiles * 32 iters / 4 = 40: one-to-two tiles' worth.
            assert_eq!(i, 40);
        }
    }

    #[test]
    fn hybrid_one_tile_structure() {
        let s = GemmShape::new(896, 384, 128);
        let p = plan(s, BLK, Decomposition::HybridOneTile { p: 4 });
        p.validate().unwrap();
        // w = 5 full waves DP (20 tiles) + 1 tile stream-k'd over 4 CTAs.
        assert_eq!(p.ctas.len(), 4 + 20);
        let sk_iters: Vec<u64> = p.ctas[..4].iter().map(CtaPlan::iters).collect();
        assert_eq!(sk_iters.iter().sum::<u64>(), 32);
    }

    #[test]
    fn hybrid_perfect_quantization_degenerates_to_dp() {
        let s = GemmShape::new(512, 384, 128); // 4*3 = 12 tiles on p=4
        let h = plan(s, BLK, Decomposition::HybridTwoTile { p: 4 });
        let dp = plan(s, BLK, Decomposition::DataParallel);
        assert_eq!(h.ctas, dp.ctas);
    }

    #[test]
    fn single_tile_huge_k_strong_scaling() {
        // Fig 5.5: one output tile, deep k: Stream-K exposes k-parallelism.
        let s = GemmShape::new(128, 128, 384 * 32);
        let p = plan(s, BLK, Decomposition::StreamK { g: 4 });
        assert_eq!(p.num_tiles, 1);
        p.validate().unwrap();
        assert_eq!(p.ctas.len(), 4);
        assert_eq!(p.peers_per_tile(), vec![4]);
    }
}

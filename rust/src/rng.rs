//! Deterministic pseudo-random numbers (splitmix64 + xoshiro256**).
//!
//! Every corpus, matrix generator and sampled experiment in this repo is
//! seeded, so figures regenerate bit-identically.  We implement the
//! generators locally to keep the runtime dependency surface at just the
//! PJRT crate.

/// xoshiro256** with splitmix64 seeding — fast, high-quality, deterministic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Log-uniform in [lo, hi) — the sampling law of the paper's Fig. 5.6
    /// GEMM-shape domain ("log-sampled at random ... six orders of
    /// magnitude").
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        (self.range_f64(lo.ln(), hi.ln())).exp()
    }

    /// Exponential inter-arrival gap with the given rate (mean `1/rate`)
    /// via inverse-CDF — the Poisson-process step of the ingest arrival
    /// traces.  Always finite and non-negative: `1 - f64()` is in (0, 1].
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Zipf-like sample in [1, n] with exponent `alpha` (rejection-free
    /// inverse-CDF approximation) — drives the power-law row-length
    /// distributions of scale-free graphs.
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        // Inverse-transform on the continuous bounded Pareto envelope.
        let n = n as f64;
        let a1 = 1.0 - alpha;
        let u = self.f64();
        let x = if (a1.abs()) < 1e-9 {
            n.powf(u)
        } else {
            (u * (n.powf(a1) - 1.0) + 1.0).powf(1.0 / a1)
        };
        (x.floor() as usize).clamp(1, n as usize)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // For small k relative to n use a set-based approach.
        if k * 4 < n {
            let mut seen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.below(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exponential_is_nonnegative_with_mean_near_inverse_rate() {
        let mut r = Rng::new(29);
        let rate = 2000.0;
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.exponential(rate);
            assert!(v.is_finite() && v >= 0.0, "bad sample {v}");
            sum += v;
        }
        let mean = sum / n as f64;
        assert!(
            (mean - 1.0 / rate).abs() < 0.05 / rate,
            "mean={mean}, want ~{}",
            1.0 / rate
        );
    }

    #[test]
    fn log_uniform_spans_orders_of_magnitude() {
        let mut r = Rng::new(3);
        let mut lo_hits = 0;
        let mut hi_hits = 0;
        for _ in 0..10_000 {
            let v = r.log_uniform(128.0, 8192.0);
            assert!((128.0..8192.0).contains(&v));
            if v < 256.0 {
                lo_hits += 1;
            }
            if v > 4096.0 {
                hi_hits += 1;
            }
        }
        // log-uniform: each octave equally likely (6 octaves in range).
        assert!(lo_hits > 1000 && hi_hits > 1000, "{lo_hits} {hi_hits}");
    }

    #[test]
    fn zipf_bounds_and_skew() {
        let mut r = Rng::new(5);
        let mut ones = 0;
        for _ in 0..10_000 {
            let v = r.zipf(1000, 2.0);
            assert!((1..=1000).contains(&v));
            if v == 1 {
                ones += 1;
            }
        }
        // alpha=2 Zipf: P(1) dominates.
        assert!(ones > 4000, "ones={ones}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        for &(n, k) in &[(1000usize, 10usize), (100, 90), (50, 50)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }
}

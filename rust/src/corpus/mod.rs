//! Evaluation corpora.
//!
//! * [`gemm_shapes`] — the Fig. 5.6 domain: 32,824 GEMM problem shapes,
//!   m/n/k log-sampled over a volume spanning six orders of magnitude.
//! * [`sparse_corpus`] — the SuiteSparse substitution: a deterministic
//!   synthetic collection spanning the row-length-distribution regimes of
//!   the real collection (DESIGN.md).

pub mod gemm_shapes;
pub mod sparse_corpus;

pub use gemm_shapes::{gemm_corpus, gemm_landscape_grid, GEMM_CORPUS_SIZE};
pub use sparse_corpus::{sparse_corpus, SparseEntry};

//! The SuiteSparse substitution: a deterministic synthetic collection
//! spanning the row-length-distribution regimes of the real collection —
//! regular meshes, scale-free graphs, banded solvers, circuit blocks, R-MAT
//! graphs, and the degenerate single-column "sparse vector" population CUB
//! special-cases.

use crate::sparse::{gen, stats, Csr};

/// One corpus entry: a generated matrix plus its provenance.
pub struct SparseEntry {
    pub name: String,
    pub family: &'static str,
    pub matrix: Csr,
}

impl SparseEntry {
    pub fn stats(&self) -> stats::RowStats {
        stats::row_stats(&self.matrix)
    }
}

/// Build the corpus.  `scale` in [0, 2]: 0 = tiny smoke corpus (fast
/// tests), 1 = the standard evaluation corpus (~90 matrices), 2 = extended.
pub fn sparse_corpus(scale: usize) -> Vec<SparseEntry> {
    let mut out = Vec::new();
    let (sizes, seeds_per_cfg): (&[usize], u64) = match scale {
        0 => (&[256, 1024], 1),
        1 => (&[512, 2048, 8192, 32768], 3),
        _ => (&[512, 2048, 8192, 32768, 131072], 4),
    };

    let mut push = |name: String, family: &'static str, m: Csr| {
        out.push(SparseEntry {
            name,
            family,
            matrix: m,
        });
    };

    let mut seed = 1000u64;
    for &n in sizes {
        for s in 0..seeds_per_cfg {
            seed += 1;
            // Regular FEM-like meshes.
            push(
                format!("uniform_{n}_d8_s{s}"),
                "uniform",
                gen::uniform(n, n, 8, seed),
            );
            seed += 1;
            push(
                format!("uniform_{n}_d32_s{s}"),
                "uniform",
                gen::uniform(n, n, 32.min(n / 4).max(2), seed),
            );
            // Scale-free graphs (the imbalance stress cases).
            seed += 1;
            push(
                format!("powerlaw_{n}_a13_s{s}"),
                "power-law",
                gen::power_law(n, n, n / 2, 1.3, seed),
            );
            seed += 1;
            push(
                format!("powerlaw_{n}_a20_s{s}"),
                "power-law",
                gen::power_law(n, n, n / 2, 2.0, seed),
            );
            // Banded stencils.
            seed += 1;
            push(format!("banded_{n}_b4_s{s}"), "banded", gen::banded(n, 4, seed));
            // Circuit-style block diagonals.
            seed += 1;
            push(
                format!("blockdiag_{n}_b16_s{s}"),
                "block-diag",
                gen::block_diag(n, 16, seed),
            );
        }
        // R-MAT graphs at matching scale (one per size).
        let sc = (n as f64).log2().round() as u32;
        seed += 1;
        push(
            format!("rmat_{n}_e8"),
            "rmat",
            gen::rmat(sc.min(17), 8, seed),
        );
        // Sparse vectors (cols == 1): the CUB heuristic population.
        seed += 1;
        push(
            format!("spvec_{n}"),
            "sparse-vector",
            gen::tall_skinny(n, 0.4, seed),
        );
        // Wide-short aspect ratio.
        seed += 1;
        push(
            format!("wideshort_{n}"),
            "wide-short",
            gen::wide_short((n / 64).max(8), n, 48.min(n / 8).max(2), seed),
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_corpus_builds() {
        let c = sparse_corpus(0);
        assert!(c.len() >= 15, "{}", c.len());
        for e in &c {
            assert!(e.matrix.nnz() > 0, "{} empty", e.name);
        }
    }

    #[test]
    fn corpus_is_deterministic() {
        let a = sparse_corpus(0);
        let b = sparse_corpus(0);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.matrix, y.matrix);
        }
    }

    #[test]
    fn corpus_spans_regularity_regimes() {
        let c = sparse_corpus(0);
        let cvs: Vec<f64> = c.iter().map(|e| e.stats().cv).collect();
        let min_cv = cvs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max_cv = cvs.iter().cloned().fold(0.0, f64::max);
        assert!(min_cv < 0.05, "has regular members (min_cv={min_cv})");
        assert!(max_cv > 1.0, "has skewed members (max_cv={max_cv})");
    }

    #[test]
    fn corpus_contains_sparse_vectors() {
        let c = sparse_corpus(0);
        assert!(c.iter().any(|e| e.matrix.cols == 1));
    }
}

//! The Fig. 5.6 GEMM-shape domain: "32,824 different problem sizes and
//! shapes, log-sampled at random within a domain of m, n, and k matrix
//! dimensions whose volume spans six orders of magnitude"
//! (m, n, k ∈ {128 … 8192}).

use crate::rng::Rng;
use crate::streamk::GemmShape;

/// 32,768 log-sampled shapes + 56 structured power-of-two corners = the
/// paper's 32,824.
pub const GEMM_CORPUS_SIZE: usize = 32_824;

const LO: f64 = 128.0;
const HI: f64 = 8192.0;
const SEED: u64 = 0x5EED_6EB3;

/// Deterministic full corpus.
pub fn gemm_corpus() -> Vec<GemmShape> {
    let mut out = Vec::with_capacity(GEMM_CORPUS_SIZE);
    let mut rng = Rng::new(SEED);
    for _ in 0..32_768 {
        let m = rng.log_uniform(LO, HI + 1.0).round() as usize;
        let n = rng.log_uniform(LO, HI + 1.0).round() as usize;
        let k = rng.log_uniform(LO, HI + 1.0).round() as usize;
        out.push(GemmShape::new(m, n, k));
    }
    // 56 structured corners: all power-of-two (m, n, k) with the three axes
    // drawn from {128, 1024, 8192} plus deep/flat extremes — 27 grid points
    // + 29 aspect-ratio extremes.
    let axis = [128usize, 1024, 8192];
    for &m in &axis {
        for &n in &axis {
            for &k in &axis {
                out.push(GemmShape::new(m, n, k));
            }
        }
    }
    let extremes = [
        (128, 8192, 128),
        (8192, 128, 128),
        (128, 128, 8192),
        (8192, 8192, 128),
        (128, 8192, 8192),
        (8192, 128, 8192),
        (256, 256, 256),
        (512, 512, 512),
        (2048, 2048, 2048),
        (4096, 4096, 4096),
        (384, 384, 128),
        (896, 384, 128),
        (128, 128, 12288),
        (256, 4096, 256),
        (4096, 256, 256),
        (640, 640, 640),
        (1280, 1280, 1280),
        (2560, 2560, 2560),
        (5120, 5120, 5120),
        (768, 768, 3072),
        (3072, 768, 768),
        (768, 3072, 768),
        (1536, 1536, 1536),
        (6144, 6144, 192),
        (192, 6144, 6144),
        (6144, 192, 6144),
        (224, 224, 224),
        (7168, 7168, 7168),
        (1024, 1024, 65536 / 8),
    ];
    for &(m, n, k) in &extremes {
        out.push(GemmShape::new(m, n, k));
    }
    debug_assert_eq!(out.len(), GEMM_CORPUS_SIZE);
    out
}

/// Downscaled Stream-K-style geometry grid for the deterministic
/// `landscape` bench: every (m, n, k) combination over a small
/// power-of-two-ish axis set, plus the aspect-ratio extremes (deep-k,
/// tall-m, wide-n) that stress the MAC-iteration tile set the way Fig. 5.6
/// stresses full Stream-K.  Host-affordable (plans only, no numerics) and
/// fully enumerable — the CI perf gate diffs per-family geomeans over it,
/// so membership must never depend on sampling.
pub fn gemm_landscape_grid(scale: usize) -> Vec<GemmShape> {
    let axis: &[usize] = if scale == 0 {
        &[64, 128]
    } else {
        &[64, 128, 192, 256]
    };
    let mut out = Vec::new();
    for &m in axis {
        for &n in axis {
            for &k in axis {
                out.push(GemmShape::new(m, n, k));
            }
        }
    }
    if scale >= 1 {
        // Downscaled Fig. 5.6 extremes: one long axis against two short.
        let extremes = [
            (64, 64, 1024),
            (1024, 64, 64),
            (64, 1024, 64),
            (512, 64, 256),
            (64, 512, 256),
            (96, 96, 96),
        ];
        for &(m, n, k) in &extremes {
            out.push(GemmShape::new(m, n, k));
        }
    }
    out
}

/// Deterministic sub-sample (stride) for heavier per-shape evaluations.
pub fn gemm_corpus_sample(n: usize) -> Vec<GemmShape> {
    let full = gemm_corpus();
    if n >= full.len() {
        return full;
    }
    let stride = full.len() / n;
    full.into_iter().step_by(stride.max(1)).take(n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_size_matches_paper() {
        assert_eq!(gemm_corpus().len(), 32_824);
    }

    #[test]
    fn corpus_is_deterministic() {
        let a = gemm_corpus();
        let b = gemm_corpus();
        assert_eq!(a[..100], b[..100]);
        assert_eq!(a[32_000], b[32_000]);
    }

    #[test]
    fn corpus_within_domain() {
        for s in gemm_corpus() {
            assert!((128..=8192 + 1).contains(&s.m), "{s:?}");
            assert!((128..=8192 + 1).contains(&s.n), "{s:?}");
            assert!(s.k >= 128, "{s:?}");
        }
    }

    #[test]
    fn volume_spans_six_orders() {
        let vols: Vec<f64> = gemm_corpus().iter().map(|s| s.flops()).collect();
        let min = vols.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = vols.iter().cloned().fold(0.0, f64::max);
        assert!(max / min > 1e5, "span {}", max / min);
    }

    #[test]
    fn sample_is_subset_and_sized() {
        let s = gemm_corpus_sample(500);
        assert!(s.len() >= 500 && s.len() <= 520);
    }

    #[test]
    fn landscape_grid_deterministic_and_scaled() {
        let small = gemm_landscape_grid(0);
        let full = gemm_landscape_grid(1);
        assert_eq!(small.len(), 8);
        assert_eq!(full.len(), 64 + 6);
        assert_eq!(full, gemm_landscape_grid(1));
        // Extremes give the grid real aspect-ratio spread.
        let max_k = full.iter().map(|s| s.k).max().unwrap();
        let max_m = full.iter().map(|s| s.m).max().unwrap();
        assert_eq!(max_k, 1024);
        assert_eq!(max_m, 1024);
    }
}

//! Deterministic landscape bench (`cargo bench --bench landscape [scale]`):
//! the adaptive tuner swept over the sparse corpus + downscaled GEMM
//! geometry grid with proxy cost feedback, written to
//! `BENCH_landscape.json` — the artifact the CI perf-regression gate diffs
//! against the committed `BENCH_baseline.json`.
//!
//! Proxy metrics (plan shape, not wall-clock) make the output bit-stable
//! on shared runners; see `serve::landscape`.

use gpulb::serve::landscape;

fn main() {
    let scale: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    println!("# landscape — scale {scale}, {} rounds", landscape::DEFAULT_ROUNDS);
    landscape::run_bench(
        scale,
        landscape::DEFAULT_ROUNDS,
        landscape::DEFAULT_PLAN_WORKERS,
        "BENCH_landscape.json",
    )
    .unwrap();
}

//! Serve-engine throughput (`cargo bench --bench serve_throughput [scale]`):
//! the heterogeneous corpus mix executed at 1, 2, 4 and 8 worker threads,
//! written to `BENCH_serve.json` (the CI bench artifact).
//!
//! Checksums are asserted equal across thread counts, so every run doubles
//! as a concurrency correctness check of the pool + plan cache.

use gpulb::serve;

fn main() {
    let scale: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let batches = 2usize;
    let mix = serve::corpus_mix(scale);
    println!(
        "# serve throughput — {} problems/batch (scale {scale}), {batches} batches per point",
        mix.len()
    );
    serve::run_bench(
        &mix,
        &[1, 2, 4, 8],
        batches,
        serve::ServeConfig::default(),
        "BENCH_serve.json",
    )
    .unwrap();
}

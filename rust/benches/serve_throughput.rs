//! Serve-engine throughput (`cargo bench --bench serve_throughput [scale]`):
//! the heterogeneous corpus mix executed at 1, 2, 4 and 8 worker threads,
//! written to `BENCH_serve.json` (the CI bench artifact).
//!
//! `cargo bench --bench serve_throughput -- single-large` runs the
//! single-large-problem mode instead: one SpMV with >= 1M nonzeros, the
//! case intra-problem worker-shard splitting exists for, written to
//! `BENCH_serve_single.json`.
//!
//! Checksums are asserted equal across thread counts, so every run doubles
//! as a concurrency correctness check of the pool + plan cache + two-phase
//! shard reduction.

use gpulb::serve;

fn main() {
    let arg = std::env::args().nth(1).unwrap_or_default();
    let batches = 2usize;
    if arg == "single-large" {
        let out = "BENCH_serve_single.json";
        let speedup = serve::run_single_large_bench(&[1, 2, 4, 8], batches, out).unwrap();
        println!("# single-large 8-vs-1 thread speedup: x{speedup:.2}");
        return;
    }
    let scale: usize = arg.parse().ok().unwrap_or(1);
    let mix = serve::corpus_mix(scale);
    println!(
        "# serve throughput — {} problems/batch (scale {scale}), {batches} batches per point",
        mix.len()
    );
    serve::run_bench(
        &mix,
        &[1, 2, 4, 8],
        batches,
        serve::ServeConfig::builder().build().unwrap(),
        "BENCH_serve.json",
    )
    .unwrap();
}

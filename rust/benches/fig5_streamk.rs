//! Chapter-5 benchmarks (`cargo bench --bench fig5_streamk`): one group per
//! paper artifact, measuring the real coordinator hot paths.
//!
//! * fig5_1_2/* — quantization arithmetic + plan construction.
//! * fig5_4/*   — the analytical grid-size model (runs per kernel launch —
//!   the cost that replaced cuBLAS's kernel-selection heuristics).
//! * fig5_7_9/* — full per-shape evaluation pipeline (plan + sim) for
//!   Stream-K vs the ensembles.
//! * table5/*   — corpus-sample sweep throughput.

use gpulb::baselines::vendor_gemm;
use gpulb::benchutil::Bencher;
use gpulb::corpus::gemm_shapes;
use gpulb::exec::gemm;
use gpulb::report::figures;
use gpulb::sim::gpu::{GpuSpec, Precision};
use gpulb::streamk::{self, decomp, Blocking, Decomposition, GemmShape};

fn main() {
    let mut b = Bencher::default();
    let gpu = GpuSpec::a100();
    let prec = Precision::F16F32;
    let blk = Blocking::paper_default(prec);
    let model = vendor_gemm::member_cost_model(&gpu, blk, prec);

    println!("# Fig 5.1/5.2 — plan construction");
    let big = GemmShape::new(4096, 4096, 4096);
    b.bench("fig5_1_2/plan_data_parallel", || {
        decomp::plan(big, blk, Decomposition::DataParallel)
    });
    b.bench("fig5_1_2/plan_stream_k_g108", || {
        decomp::plan(big, blk, Decomposition::StreamK { g: 108 })
    });
    b.bench("fig5_1_2/plan_hybrid_two_tile", || {
        decomp::plan(big, blk, Decomposition::HybridTwoTile { p: 108 })
    });

    println!("\n# Fig 5.4 — grid-size model (per-launch selection cost)");
    b.bench("fig5_4/best_grid", || {
        streamk::best_grid(GemmShape::new(1024, 1024, 2048), blk, 108, &model)
    });
    b.bench("fig5_4/model_curve_108", || {
        streamk::model::model_curve(GemmShape::new(1024, 1024, 2048), blk, 108, &model)
    });

    println!("\n# Fig 5.7–5.9 — per-shape evaluation pipeline (plan + sim)");
    let shape = GemmShape::new(2000, 1500, 3000);
    b.bench("fig5_7_9/streamk_eval", || {
        figures::streamk_time(shape, &gpu, prec)
    });
    b.bench("fig5_7_9/dp_eval", || {
        vendor_gemm::member_time(shape, blk, 1, &gpu, prec)
    });
    b.bench("fig5_7_9/cublas_heuristic_eval", || {
        vendor_gemm::cublas_like_time(shape, &gpu, prec)
    });
    b.bench("fig5_7_9/oracle_eval", || {
        vendor_gemm::oracle_time(shape, &gpu, prec)
    });
    b.bench("fig5_7_9/simulate_plan_sk", || {
        let plan = decomp::plan(shape, blk, Decomposition::StreamK { g: 108 });
        gemm::simulate_plan(&plan, &model, &gpu, prec)
    });

    println!("\n# Tables 5.1/5.2 — corpus sweep throughput (100 shapes)");
    let sample = gemm_shapes::gemm_corpus_sample(100);
    b.bench("table5/sweep_100_shapes_streamk", || {
        sample
            .iter()
            .map(|&s| figures::streamk_time(s, &gpu, prec))
            .sum::<f64>()
    });
}

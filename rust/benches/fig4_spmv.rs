//! Chapter-4 benchmarks (`cargo bench --bench fig4_spmv`): one bench group
//! per paper artifact.
//!
//! * fig4_2/* — framework merge-path vs hardwired-CUB pipeline cost (the
//!   abstraction-overhead experiment, measured on the real Rust hot path:
//!   schedule construction + execution).
//! * fig4_3/* — per-schedule SpMV pipeline on irregular vs regular inputs.
//! * fig4_4/* — heuristic-combined pipeline (selection + assignment + exec).
//! * fig6_1/* — oracle sweep over all schedules.

use gpulb::balance::{self, ScheduleKind};
use gpulb::benchutil::Bencher;
use gpulb::exec::spmv;
use gpulb::sparse::gen;

fn main() {
    let mut b = Bencher::default();
    let workers = 80 * 128;

    let irregular = gen::power_law(8192, 8192, 4096, 1.7, 1);
    let regular = gen::uniform(8192, 8192, 16, 2);
    let x: Vec<f64> = (0..8192).map(|i| (i as f64 * 0.3).sin()).collect();

    println!("# Fig 4.2 — abstraction overhead: fused CUB-style vs framework pipeline");
    // "CUB": hardwired merge-path — search and consume welded together, no
    // materialized assignment.
    b.bench("fig4_2/cub_fused_exec", || {
        gpulb::baselines::cub_spmv::execute_fused(&irregular, &x, workers)
    });
    b.bench("fig4_2/framework_exec", || {
        // Framework path: build the generic assignment, then execute it.
        let asg = ScheduleKind::MergePath.assign(&irregular, workers);
        spmv::execute_host(&irregular, &x, &asg)
    });
    // Amortized reuse (iterative solvers rebuild the schedule once):
    let asg_reused = ScheduleKind::MergePath.assign(&irregular, workers);
    b.bench("fig4_2/framework_exec_amortized", || {
        spmv::execute_host(&irregular, &x, &asg_reused)
    });

    println!("\n# Fig 4.3 — schedule pipelines (assignment + execution)");
    for kind in [
        ScheduleKind::ThreadMapped,
        ScheduleKind::GroupMapped(32),
        ScheduleKind::MergePath,
        ScheduleKind::NonzeroSplit,
        ScheduleKind::Binning,
        ScheduleKind::Lrb,
    ] {
        b.bench(&format!("fig4_3/{}/irregular", kind.name()), || {
            let asg = kind.assign(&irregular, workers);
            spmv::execute_host(&irregular, &x, &asg)
        });
        b.bench(&format!("fig4_3/{}/regular", kind.name()), || {
            let asg = kind.assign(&regular, workers);
            spmv::execute_host(&regular, &x, &asg)
        });
    }

    println!("\n# Fig 4.4 — heuristic-combined pipeline");
    b.bench("fig4_4/heuristic_select_and_run", || {
        let kind = balance::select_schedule(&irregular, balance::HeuristicParams::default());
        let asg = kind.assign(&irregular, workers);
        spmv::execute_host(&irregular, &x, &asg)
    });

    println!("\n# Fig 6.1 — oracle sweep (all schedules, pick fastest)");
    b.bench("fig6_1/oracle_sweep_small", || {
        let a = gen::power_law(1024, 1024, 512, 1.8, 3);
        let mut best = f64::INFINITY;
        let gpu = gpulb::sim::GpuSpec::v100();
        let cost = gpulb::sim::SpmvCost::calibrate(&gpu);
        for kind in [
            ScheduleKind::ThreadMapped,
            ScheduleKind::GroupMapped(32),
            ScheduleKind::MergePath,
        ] {
            let asg = kind.assign(&a, workers);
            best = best.min(spmv::modeled_time(&a, &asg, Some(kind), &cost, &gpu));
        }
        best
    });
}

//! Hot-path microbenchmarks (`cargo bench --bench hot_paths`) — the §Perf
//! targets from DESIGN.md.  These are the operations on the coordinator's
//! critical path:
//!
//! * merge-path 2-D diagonal search (per-thread partition cost);
//! * lower-bound search (nonzero splitting);
//! * LRB / three-bin binning throughput;
//! * schedule assignment end-to-end;
//! * block-scheduler simulation throughput;
//! * queue-policy simulation;
//! * PJRT dispatch (only when artifacts are present).

use gpulb::balance::{binning, merge_path, nonzero_split, search, thread_mapped};
use gpulb::benchutil::Bencher;
use gpulb::sim::{self, CtaWork, GpuSpec};
use gpulb::sparse::gen;

fn main() {
    let mut b = Bencher::default();

    let a = gen::power_law(65_536, 65_536, 16_384, 1.7, 1);
    let offsets = &a.offsets;
    let total = a.rows + a.nnz();

    println!("# search primitives");
    b.bench("search/merge_path_search_1k_diags", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            let d = (i * 7919) % (total + 1);
            acc += search::merge_path_search(offsets, d).0;
        }
        acc
    });
    b.bench("search/lower_bound_1k", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            acc += search::lower_bound(offsets, (i * 104_729) % (a.nnz() + 1));
        }
        acc
    });

    println!("\n# schedule assignment (65k x 65k power-law, 10240 workers)");
    b.bench("assign/thread_mapped", || thread_mapped::assign(&a, 10_240));
    b.bench("assign/merge_path", || merge_path::assign(&a, 10_240));
    b.bench("assign/nonzero_split", || nonzero_split::assign(&a, 10_240));
    b.bench("assign/binning", || binning::assign(&a, 10_240));
    b.bench("assign/lrb", || binning::assign_lrb(&a, 10_240));

    println!("\n# block-scheduler simulation");
    let gpu = GpuSpec::a100();
    let ctas_10k: Vec<CtaWork> = (0..10_000)
        .map(|i| CtaWork::new(1.0 + (i % 13) as f64 * 0.1))
        .collect();
    b.bench("sim/schedule_10k_ctas", || sim::simulate(&gpu, &ctas_10k));

    println!("\n# queue policies (1k tasks, 80 workers)");
    use gpulb::balance::queue::{simulate, QueueParams, QueuePolicy};
    let tasks: Vec<usize> = (0..1000).map(|i| 1 + (i * 31) % 500).collect();
    for policy in [
        QueuePolicy::Centralized,
        QueuePolicy::Stealing,
        QueuePolicy::ChunkedFetch { chunk: 16 },
    ] {
        b.bench(&format!("queue/{policy:?}"), || {
            simulate(policy, 80, tasks.clone(), |_| Vec::new(), QueueParams::default())
        });
    }

    // PJRT dispatch (the request-path kernel-invocation cost).
    if let Ok(rt) = gpulb::runtime::Runtime::open("artifacts") {
        println!("\n# PJRT dispatch (gemm_mac_iter_f32, 128x128x32)");
        rt.warmup(&["gemm_mac_iter_f32"]).unwrap();
        let a_in = gpulb::runtime::HostTensor::F32(vec![1.0; 128 * 32], vec![128, 32]);
        let b_in = gpulb::runtime::HostTensor::F32(vec![1.0; 32 * 128], vec![32, 128]);
        let acc = gpulb::runtime::HostTensor::F32(vec![0.0; 128 * 128], vec![128, 128]);
        b.bench("runtime/mac_iter_dispatch", || {
            rt.execute(
                "gemm_mac_iter_f32",
                &[a_in.clone(), b_in.clone(), acc.clone()],
            )
            .unwrap()
        });
        // 16-iteration accumulate chain: host round trip per step vs the
        // device-resident accumulator (§Perf: device-buffer chaining).
        b.bench("runtime/chain16_host_roundtrip", || {
            let mut acc_h = acc.clone();
            for _ in 0..16 {
                acc_h = rt
                    .execute("gemm_mac_iter_f32", &[a_in.clone(), b_in.clone(), acc_h])
                    .unwrap();
            }
            acc_h
        });
        b.bench("runtime/chain16_device_resident", || {
            use gpulb::runtime::DevInput;
            let mut acc_d = rt.to_device(&acc).unwrap();
            for _ in 0..16 {
                acc_d = rt
                    .execute_dev(
                        "gemm_mac_iter_f32",
                        &[
                            DevInput::Host(a_in.clone()),
                            DevInput::Host(b_in.clone()),
                            DevInput::Dev(&acc_d),
                        ],
                    )
                    .unwrap();
            }
            rt.to_host(&acc_d).unwrap()
        });
    } else {
        println!("\n(artifacts absent: skipping PJRT dispatch bench)");
    }
}

//! Hot-path microbenchmarks (`cargo bench --bench hot_paths`) — the raw
//! host-lane speed targets of ROADMAP item 3, documented in README
//! "Raw speed".  These are the operations on the coordinator's critical
//! path:
//!
//! * merge-path 2-D diagonal search (per-thread partition cost);
//! * incremental merge-path walker vs per-worker binary search (the
//!   plan-build hot loop);
//! * SpMV segment inner loop: serial left fold vs the 4-lane block tree;
//! * SpGEMM batch flush: fresh slab vs reusable arena;
//! * lower-bound search (nonzero splitting);
//! * LRB / three-bin binning throughput;
//! * schedule assignment end-to-end;
//! * block-scheduler simulation throughput;
//! * queue-policy simulation;
//! * PJRT dispatch (only when artifacts are present).
//!
//! Flags (after `--`): `--quick` (short smoke-run windows), `--out PATH`
//! (write the per-op `BENCH_hot_paths.json` artifact), `--gate` (enforce
//! the self-relative speedup floors: walker vs binary-search plan build
//! and lane vs serial SpMV inner loop, both measured within this run so
//! absolute runner speed cancels), `--min-walker-speedup F` (default
//! 1.2), `--min-simd-speedup F` (default 1.1).

use gpulb::balance::{binning, merge_path, nonzero_split, search, stream, thread_mapped};
use gpulb::balance::{OffsetsSource, ScheduleKind};
use gpulb::benchutil::{family_json_with_unit, Bencher, Direction, FamilyPoint};
use gpulb::exec::{lanes, spgemm};
use gpulb::sim::{self, CtaWork, GpuSpec};
use gpulb::sparse::gen;

struct Opts {
    quick: bool,
    gate: bool,
    out: Option<String>,
    min_walker_speedup: f64,
    min_simd_speedup: f64,
}

fn parse_opts() -> Opts {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts = Opts {
        quick: false,
        gate: false,
        out: None,
        min_walker_speedup: 1.2,
        min_simd_speedup: 1.1,
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => opts.quick = true,
            "--gate" => opts.gate = true,
            "--out" => {
                i += 1;
                opts.out = Some(args.get(i).expect("--out requires a path").clone());
            }
            "--min-walker-speedup" => {
                i += 1;
                opts.min_walker_speedup = args
                    .get(i)
                    .expect("--min-walker-speedup requires a number")
                    .parse()
                    .expect("--min-walker-speedup must be a float");
            }
            "--min-simd-speedup" => {
                i += 1;
                opts.min_simd_speedup = args
                    .get(i)
                    .expect("--min-simd-speedup requires a number")
                    .parse()
                    .expect("--min-simd-speedup must be a float");
            }
            // Cargo may forward harness-style flags; ignore them.
            "--bench" => {}
            other => eprintln!("hot_paths: ignoring unknown arg {other:?}"),
        }
        i += 1;
    }
    opts
}

fn median_of(b: &Bencher, name: &str) -> f64 {
    b.results()
        .iter()
        .find(|r| r.name == name)
        .unwrap_or_else(|| panic!("bench row {name:?} missing"))
        .ns_per_iter_median
}

fn main() {
    let opts = parse_opts();
    let mut b = if opts.quick {
        Bencher::quick()
    } else {
        Bencher::default()
    };

    let a = gen::power_law(65_536, 65_536, 16_384, 1.7, 1);
    let offsets = &a.offsets;
    let total = a.rows + a.nnz();
    let workers = 10_240usize;
    let per_diag = total.div_ceil(workers).max(1);

    println!("# search primitives");
    b.bench("search/merge_path_search_1k_diags", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            let d = (i * 7919) % (total + 1);
            acc += search::merge_path_search(offsets, d).0;
        }
        acc
    });
    b.bench("search/lower_bound_1k", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            acc += search::lower_bound(offsets, (i * 104_729) % (a.nnz() + 1));
        }
        acc
    });

    // The gated pair #1: resolving every worker boundary of a 10_240-way
    // merge-path plan — what every stream walk used to pay as two binary
    // searches per worker vs what the incremental walker pays now.
    println!("\n# plan build: worker boundaries, binary search vs incremental walker");
    b.bench("plan/merge_path_boundaries_search", || {
        let mut acc = 0usize;
        for w in 0..=workers {
            acc += search::merge_path_search(offsets, (w * per_diag).min(total)).0;
        }
        acc
    });
    b.bench("plan/merge_path_boundaries_walker", || {
        let mut walker = search::MergePathWalker::new(offsets);
        let mut acc = 0usize;
        for w in 0..=workers {
            acc += walker.advance_to((w * per_diag).min(total)).0;
        }
        acc
    });

    // The gated pair #2: the SpMV segment inner loop on an L1/L2-resident
    // gather target — the serial left fold the executors used before
    // exec/lanes.rs vs the 4-lane block tree (both builds always compile
    // both; the `simd` feature only picks the production dispatch).
    println!("\n# spmv inner loop: serial fold vs 4-lane block tree");
    let seg_len = 65_536usize;
    let xs_len = 4096usize;
    let seg_values: Vec<f64> = (0..seg_len).map(|i| (i as f64 * 0.37).sin()).collect();
    let seg_indices: Vec<u32> = (0..seg_len)
        .map(|i| ((i * 2654435761) % xs_len) as u32)
        .collect();
    let xs: Vec<f64> = (0..xs_len).map(|i| (i as f64 * 0.17).cos()).collect();
    b.bench("spmv/inner_linear", || {
        lanes::gather_dot_linear(&seg_values, &seg_indices, &xs)
    });
    b.bench("spmv/inner_lanes", || {
        lanes::gather_dot_lanes(&seg_values, &seg_indices, &xs)
    });

    println!("\n# spgemm batch flush: fresh slab vs reusable arena");
    let sa = gen::power_law(512, 512, 128, 1.7, 7);
    let sb = gen::uniform(512, 256, 4, 8);
    let work = spgemm::work_offsets(&sa, &sb);
    let src = OffsetsSource::new(&work);
    let desc = ScheduleKind::MergePath
        .descriptor(&src, 64)
        .expect("merge-path streams");
    let scatter = |slab: &mut spgemm::RowSlab| {
        stream::for_each_segment(desc, &work, |s| {
            spgemm::for_each_segment_product(&sa, &sb, &work, s, |col, v| {
                slab.push_one(s.tile, col, v);
            });
        });
    };
    b.bench("spgemm/flush_fresh_slab", || {
        let mut slab = spgemm::RowSlab::new(&work);
        scatter(&mut slab);
        spgemm::checksum(&slab.finalize(sa.rows, sb.cols))
    });
    let mut arena = spgemm::RowSlab::new(&work);
    b.bench("spgemm/flush_arena_reuse", || {
        arena.reset(&work);
        scatter(&mut arena);
        arena.checksum_merged(sa.rows)
    });

    println!("\n# schedule assignment (65k x 65k power-law, 10240 workers)");
    b.bench("assign/thread_mapped", || thread_mapped::assign(&a, 10_240));
    b.bench("assign/merge_path", || merge_path::assign(&a, 10_240));
    b.bench("assign/nonzero_split", || nonzero_split::assign(&a, 10_240));
    b.bench("assign/binning", || binning::assign(&a, 10_240));
    b.bench("assign/lrb", || binning::assign_lrb(&a, 10_240));

    println!("\n# block-scheduler simulation");
    let gpu = GpuSpec::a100();
    let ctas_10k: Vec<CtaWork> = (0..10_000)
        .map(|i| CtaWork::new(1.0 + (i % 13) as f64 * 0.1))
        .collect();
    b.bench("sim/schedule_10k_ctas", || sim::simulate(&gpu, &ctas_10k));

    println!("\n# queue policies (1k tasks, 80 workers)");
    use gpulb::balance::queue::{simulate, QueueParams, QueuePolicy};
    let tasks: Vec<usize> = (0..1000).map(|i| 1 + (i * 31) % 500).collect();
    for policy in [
        QueuePolicy::Centralized,
        QueuePolicy::Stealing,
        QueuePolicy::ChunkedFetch { chunk: 16 },
    ] {
        b.bench(&format!("queue/{policy:?}"), || {
            simulate(policy, 80, tasks.clone(), |_| Vec::new(), QueueParams::default())
        });
    }

    // PJRT dispatch (the request-path kernel-invocation cost).
    if let Ok(rt) = gpulb::runtime::Runtime::open("artifacts") {
        println!("\n# PJRT dispatch (gemm_mac_iter_f32, 128x128x32)");
        rt.warmup(&["gemm_mac_iter_f32"]).unwrap();
        let a_in = gpulb::runtime::HostTensor::F32(vec![1.0; 128 * 32], vec![128, 32]);
        let b_in = gpulb::runtime::HostTensor::F32(vec![1.0; 32 * 128], vec![32, 128]);
        let acc = gpulb::runtime::HostTensor::F32(vec![0.0; 128 * 128], vec![128, 128]);
        b.bench("runtime/mac_iter_dispatch", || {
            rt.execute(
                "gemm_mac_iter_f32",
                &[a_in.clone(), b_in.clone(), acc.clone()],
            )
            .unwrap()
        });
        // 16-iteration accumulate chain: host round trip per step vs the
        // device-resident accumulator (§Perf: device-buffer chaining).
        b.bench("runtime/chain16_host_roundtrip", || {
            let mut acc_h = acc.clone();
            for _ in 0..16 {
                acc_h = rt
                    .execute("gemm_mac_iter_f32", &[a_in.clone(), b_in.clone(), acc_h])
                    .unwrap();
            }
            acc_h
        });
        b.bench("runtime/chain16_device_resident", || {
            use gpulb::runtime::DevInput;
            let mut acc_d = rt.to_device(&acc).unwrap();
            for _ in 0..16 {
                acc_d = rt
                    .execute_dev(
                        "gemm_mac_iter_f32",
                        &[
                            DevInput::Host(a_in.clone()),
                            DevInput::Host(b_in.clone()),
                            DevInput::Dev(&acc_d),
                        ],
                    )
                    .unwrap();
            }
            rt.to_host(&acc_d).unwrap()
        });
    } else {
        println!("\n(artifacts absent: skipping PJRT dispatch bench)");
    }

    // Per-op artifact rows: one lower-is-better ns/op family per bench.
    if let Some(path) = &opts.out {
        let points: Vec<FamilyPoint> = b
            .results()
            .iter()
            .map(|r| FamilyPoint {
                family: r.name.clone(),
                problems: 1,
                geomean_throughput: r.ns_per_iter_median,
                direction: Direction::LowerIsBetter,
            })
            .collect();
        let json = family_json_with_unit("hot_paths", "ns/op", 1, &points);
        std::fs::write(path, json).expect("write hot_paths artifact");
        println!("\nwrote {path}");
    }

    // Self-relative speedup gates: numerator and denominator come from
    // this same run on this same machine, so shared-runner noise cancels
    // to first order and only the *relative* win is asserted.
    let walker_speedup = median_of(&b, "plan/merge_path_boundaries_search")
        / median_of(&b, "plan/merge_path_boundaries_walker");
    let simd_speedup = median_of(&b, "spmv/inner_linear") / median_of(&b, "spmv/inner_lanes");
    println!("\nwalker speedup vs binary-search plan build: {walker_speedup:.2}x");
    println!("lane-kernel speedup vs serial SpMV inner loop: {simd_speedup:.2}x");
    if opts.gate {
        let mut failed = false;
        if walker_speedup < opts.min_walker_speedup {
            eprintln!(
                "GATE FAIL: incremental walker {walker_speedup:.2}x < required {:.2}x",
                opts.min_walker_speedup
            );
            failed = true;
        }
        if simd_speedup < opts.min_simd_speedup {
            eprintln!(
                "GATE FAIL: lane kernel {simd_speedup:.2}x < required {:.2}x",
                opts.min_simd_speedup
            );
            failed = true;
        }
        if failed {
            std::process::exit(1);
        }
        println!(
            "gates passed (walker >= {:.2}x, simd >= {:.2}x)",
            opts.min_walker_speedup, opts.min_simd_speedup
        );
    }
}

//! The `simd` feature's cross-build contract, end to end: with the
//! feature on or off, every served checksum is **bitwise identical**,
//! because both builds compute the same canonical 4-lane block-tree
//! reduction (`exec/lanes.rs`) — only the loop shape the autovectorizer
//! sees changes.  CI runs this suite in both builds (the feature-matrix
//! leg), so the assertions here pin:
//!
//! * the lane primitives against their scalar twins, bit for bit, at
//!   every remainder length;
//! * every served kernel family × {ThreadMapped, MergePath,
//!   WorkStealing} × 1/2/4/8 threads: checksums invariant across thread
//!   counts and schedules — the same matrix `tests/dynamic_schedules.rs`
//!   pins, now load-bearing for the vectorized inner loops;
//! * the production SpMV path against an independent scalar
//!   reimplementation of the canonical order (so the dispatch wrapper
//!   cannot silently change the tree);
//! * the SpGEMM arena: a second flush reuses capacity (no growth) and
//!   matches a fresh-slab run bitwise.

use std::sync::Arc;

use gpulb::balance::{stream, OffsetsSource, ScheduleKind};
use gpulb::exec::kernel::{SpgemmKernel, WorkKernel};
use gpulb::exec::{lanes, spmv};
use gpulb::serve::{Problem, SchedulePolicy, ServeConfig, ServeEngine};
use gpulb::sparse::gen;
use gpulb::streamk::{Blocking, GemmShape};

/// One problem per kernel family, sized so every family has real skew
/// (the `dynamic_schedules` mix).
fn five_kernel_mix() -> Vec<Problem> {
    let a = Arc::new(gen::power_law(192, 192, 96, 1.6, 71));
    let b = Arc::new(gen::uniform(192, 128, 4, 72));
    let graph = Arc::new(gen::rmat(7, 4, 73));
    let frontier: Vec<u32> = (0..graph.rows as u32).step_by(2).collect();
    vec![
        Problem::spmv(a.clone()),
        Problem::spmm(a.clone(), 3),
        Problem::spgemm(a, b),
        Problem::gemm(GemmShape::new(64, 48, 40), Blocking::new(16, 16, 8), 9),
        Problem::frontier(graph, frontier),
    ]
}

fn engine(threads: usize, kind: ScheduleKind) -> ServeEngine {
    ServeEngine::new(
        ServeConfig::builder()
            .threads(threads)
            .plan_workers(64)
            .schedule(SchedulePolicy::Fixed(kind))
            .split_min_atoms(1)
            .build()
            .unwrap(),
    )
}

#[test]
fn lane_primitives_bitwise_equal_scalar_twins() {
    // Exhaustive remainder coverage (0..3 tail lanes, 0..n blocks) plus
    // irregular data: whichever impl the feature dispatches to, the other
    // must produce the same bits.
    for n in 0..67usize {
        let values: Vec<f64> = (0..n).map(|i| ((i * 37 + 11) as f64 * 0.013).sin()).collect();
        let indices: Vec<u32> = (0..n).map(|i| ((i * 53) % 97) as u32).collect();
        let x: Vec<f64> = (0..97).map(|i| (i as f64 * 0.29).cos()).collect();
        let dot_l = lanes::gather_dot_lanes(&values, &indices, &x);
        let dot_s = lanes::gather_dot_scalar(&values, &indices, &x);
        assert_eq!(dot_l.to_bits(), dot_s.to_bits(), "gather_dot n={n}");
        assert_eq!(lanes::gather_dot(&values, &indices, &x).to_bits(), dot_l.to_bits());
        let abs_l = lanes::abs_sum_lanes(&values);
        let abs_s = lanes::abs_sum_scalar(&values);
        assert_eq!(abs_l.to_bits(), abs_s.to_bits(), "abs_sum n={n}");
        assert_eq!(lanes::abs_sum(&values).to_bits(), abs_l.to_bits());
        let mut acc_l = values.clone();
        let mut acc_s = values.clone();
        let xs: Vec<f64> = (0..n).map(|i| (i as f64 * 0.41).sin()).collect();
        lanes::axpy_lanes(&mut acc_l, -0.73, &xs);
        lanes::axpy_scalar(&mut acc_s, -0.73, &xs);
        let same = acc_l.iter().zip(&acc_s).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "axpy n={n}");
    }
}

#[test]
fn spmv_production_path_matches_independent_block_tree() {
    // Reimplement the canonical 4-lane block tree from its spec, without
    // exec/lanes.rs: blocks of 4 ascending, (p0+p1)+(p2+p3) per block,
    // linear remainder.  The production executor must match bit for bit
    // in either build — this is what keeps the dispatch wrapper honest.
    let a = gen::power_law(300, 300, 150, 1.6, 21);
    let x: Vec<f64> = (0..a.cols).map(|i| (i as f64 * 0.37).sin()).collect();
    let mut want = vec![0.0f64; a.rows];
    for r in 0..a.rows {
        let (k0, k1) = (a.offsets[r], a.offsets[r + 1]);
        let n = k1 - k0;
        let main = k0 + (n - n % 4);
        let mut sum = 0.0f64;
        let mut k = k0;
        while k < main {
            let p0 = a.values[k] * x[a.indices[k] as usize];
            let p1 = a.values[k + 1] * x[a.indices[k + 1] as usize];
            let p2 = a.values[k + 2] * x[a.indices[k + 2] as usize];
            let p3 = a.values[k + 3] * x[a.indices[k + 3] as usize];
            sum += (p0 + p1) + (p2 + p3);
            k += 4;
        }
        while k < k1 {
            sum += a.values[k] * x[a.indices[k] as usize];
            k += 1;
        }
        want[r] = sum;
    }
    // Thread-mapped at 1 plan worker per row boundary keeps one segment
    // per row, so the executor's per-segment tree is the per-row tree.
    let desc = ScheduleKind::ThreadMapped
        .descriptor(&a, a.rows)
        .expect("thread-mapped streams");
    let got = spmv::execute_stream_host(&a, &x, &desc);
    assert_eq!(got.len(), want.len());
    for (r, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "row {r}: {g} vs {w}");
    }
}

#[test]
fn served_checksums_invariant_across_threads_all_schedules() {
    // Every served kernel × {ThreadMapped, MergePath, WorkStealing} ×
    // 1/2/4/8 threads: bitwise-equal checksums per (kernel, schedule),
    // and ThreadMapped == MergePath == WorkStealing per kernel (whole
    // tiles ascending == canonical segment reduction).  CI runs this with
    // the feature on and off; the engine-level checksums must be the same
    // bits in both builds.
    let mix = five_kernel_mix();
    let kinds = [
        ScheduleKind::ThreadMapped,
        ScheduleKind::MergePath,
        ScheduleKind::WorkStealing { chunk: 8 },
    ];
    let reference = engine(1, ScheduleKind::ThreadMapped)
        .execute_batch(&mix)
        .checksums;
    for kind in kinds {
        for threads in [1usize, 2, 4, 8] {
            let got = engine(threads, kind).execute_batch(&mix).checksums;
            for (i, (g, w)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "{} under {kind:?} x{threads}: {g} vs {w}",
                    mix[i].kind_name()
                );
            }
        }
    }
}

#[test]
fn spgemm_arena_reuses_capacity_and_matches_fresh_kernel_bitwise() {
    let a = Arc::new(gen::power_law(160, 160, 80, 1.6, 31));
    let b = Arc::new(gen::uniform(160, 120, 4, 32));
    let kernel = SpgemmKernel::new(a.clone(), b.clone());
    let offsets = WorkKernel::offsets(&kernel).to_vec();
    let src = OffsetsSource::new(&offsets);
    let desc = ScheduleKind::MergePath.descriptor(&src, 24).unwrap();

    // First flush warms the arena; capacity is now at its high-water mark.
    let first = WorkKernel::execute_stream(&kernel, &desc);
    let cap = kernel.arena_capacity();
    assert!(cap >= *offsets.last().unwrap(), "arena must hold every product");

    // Second flush: same bits, zero growth.
    let second = WorkKernel::execute_stream(&kernel, &desc);
    assert_eq!(second.to_bits(), first.to_bits(), "reused arena diverged");
    assert_eq!(kernel.arena_capacity(), cap, "second flush grew the arena");

    // The two-phase reduce path shares the arena too.
    let mid = desc.workers().div_ceil(2);
    let shards = vec![
        WorkKernel::shard(&kernel, &desc, 0, mid),
        WorkKernel::shard(&kernel, &desc, mid, desc.workers()),
    ];
    let reduced = WorkKernel::reduce(&kernel, shards);
    assert_eq!(reduced.to_bits(), first.to_bits(), "reduce path diverged");
    assert_eq!(kernel.arena_capacity(), cap, "reduce grew the arena");

    // And a fresh kernel lands on the same bits as the warmed one.
    let fresh = SpgemmKernel::new(a, b);
    let fresh_sum = WorkKernel::execute_stream(&fresh, &desc);
    assert_eq!(fresh_sum.to_bits(), first.to_bits(), "fresh kernel diverged");
}

#[test]
fn stream_walk_unaffected_by_lane_dispatch() {
    // The walker rewrite and the lane kernels are independent changes;
    // this pins that segment *shapes* (not just sums) are identical to
    // the per-worker iterator in whichever build runs this suite.
    let a = gen::power_law(400, 400, 200, 1.6, 17);
    for kind in [
        ScheduleKind::ThreadMapped,
        ScheduleKind::MergePath,
        ScheduleKind::NonzeroSplit,
    ] {
        let desc = kind.descriptor(&a, 48).unwrap();
        let mut walked = Vec::new();
        stream::for_each_segment(desc, &a.offsets, |s| walked.push(s));
        let legacy: Vec<_> = (0..desc.workers())
            .flat_map(|w| stream::worker_segments(desc, &a.offsets, w))
            .collect();
        assert_eq!(walked, legacy, "{kind:?} walk diverged");
    }
}

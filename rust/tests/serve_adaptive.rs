//! Adaptive schedule selection, end to end through the serve engine.
//!
//! A synthetic two-fingerprint landscape where the deterministic proxy
//! meter makes `ThreadMapped` measurably best for one work source (a ring
//! of 1-atom tiles) and the dynamic `ChunkedFetch` for the other (a few
//! huge tiles next to thousands of tiny ones — runtime chunk claiming
//! spreads the hubs where static shares stack them).  The adaptive engine
//! must converge to the per-fingerprint best for >= 90% of post-warmup
//! executions, keep checksums bit-identical to every `Fixed` run across
//! 1/2/4/8 threads (weights are 1.0, so all reductions are exact integer
//! sums), replay the same schedule trace for the same seed at any thread
//! count, and use the shape prior on a cold start.

use std::sync::Arc;

use gpulb::balance::adaptive::{proxy_cost_for, CANDIDATES};
use gpulb::balance::ScheduleKind;
use gpulb::serve::{CostFeedback, Problem, SchedulePolicy, ServeConfig, ServeEngine};
use gpulb::sparse::Csr;

const PLAN_WORKERS: usize = 64;
const SEED: u64 = 0xC0FFEE;

fn adaptive_cfg_seeded(threads: usize, seed: u64) -> ServeConfig {
    ServeConfig::builder()
        .threads(threads)
        .plan_workers(PLAN_WORKERS)
        .schedule(SchedulePolicy::Adaptive {
            epsilon: 0.02,
            min_samples: 2,
            seed,
        })
        .feedback(CostFeedback::Proxy)
        .cache_capacity(1024)
        .build()
        .unwrap()
}

fn adaptive_cfg(threads: usize) -> ServeConfig {
    adaptive_cfg_seeded(threads, SEED)
}

fn fixed_cfg(threads: usize, kind: ScheduleKind) -> ServeConfig {
    ServeConfig::builder()
        .threads(threads)
        .plan_workers(PLAN_WORKERS)
        .schedule(SchedulePolicy::Fixed(kind))
        .feedback(CostFeedback::Proxy)
        .cache_capacity(1024)
        .build()
        .unwrap()
}

/// Ring graph: every vertex has exactly one unit-weight neighbor — a
/// perfectly uniform 1-atom-per-tile work source.
fn ring_graph(n: usize) -> Arc<Csr> {
    let offsets: Vec<usize> = (0..=n).collect();
    let indices: Vec<u32> = (0..n).map(|v| ((v + 1) % n) as u32).collect();
    let values = vec![1.0; n];
    Arc::new(Csr::from_parts(n, n, offsets, indices, values).unwrap())
}

/// A few hub vertices with huge unit-weight neighbor lists next to a long
/// tail of degree-1 vertices: the mixed-skew source where runtime chunk
/// claiming (chunked fetch) beats every static plan.
fn hub_tail_graph(hubs: usize, hub_degree: usize, tail: usize) -> Arc<Csr> {
    let rows = hubs + tail;
    let cols = hub_degree;
    let mut offsets = Vec::with_capacity(rows + 1);
    let mut indices = Vec::new();
    offsets.push(0);
    for r in 0..rows {
        let len = if r < hubs { hub_degree } else { 1 };
        for i in 0..len {
            indices.push((i % cols) as u32);
        }
        offsets.push(indices.len());
    }
    let values = vec![1.0; indices.len()];
    Arc::new(Csr::from_parts(rows, cols, offsets, indices, values).unwrap())
}

fn uniform_problem() -> Problem {
    let graph = ring_graph(256);
    let frontier: Vec<u32> = (0..graph.rows as u32).collect();
    Problem::frontier(graph, frontier)
}

fn skewed_problem() -> Problem {
    let graph = hub_tail_graph(4, 4096, 4096);
    let frontier: Vec<u32> = (0..graph.rows as u32).collect();
    Problem::frontier(graph, frontier)
}

fn problem_offsets(p: &Problem) -> Vec<usize> {
    p.offsets().to_vec()
}

/// Proxy-cost argmin over the candidate set (planned and dynamic, each
/// through its own cost model) — the schedule a converged tuner must
/// settle on.
fn proxy_argmin(offsets: &[usize]) -> ScheduleKind {
    let cost = |kind: ScheduleKind| proxy_cost_for(kind, offsets, PLAN_WORKERS);
    CANDIDATES
        .iter()
        .copied()
        .min_by(|&a, &b| cost(a).total_cmp(&cost(b)))
        .unwrap()
}

/// The mix: 4 copies of each problem, interleaved, so every batch gives
/// the tuner several samples per fingerprint.
fn two_fingerprint_mix() -> Vec<Problem> {
    let (u, s) = (uniform_problem(), skewed_problem());
    let mut mix = Vec::new();
    for _ in 0..4 {
        mix.push(u.clone());
        mix.push(s.clone());
    }
    mix
}

#[test]
fn landscape_has_distinct_per_fingerprint_winners() {
    // The premise of every test below: the proxy meter separates the two
    // fingerprints with different best schedules — and the skewed one's
    // winner is *dynamic*, so convergence below proves the tuner
    // discovers runtime claiming from measured feedback alone.
    let u = proxy_argmin(&problem_offsets(&uniform_problem()));
    let s = proxy_argmin(&problem_offsets(&skewed_problem()));
    assert_eq!(u, ScheduleKind::ThreadMapped);
    assert_eq!(
        s,
        ScheduleKind::ChunkedFetch {
            chunk: gpulb::balance::dynamic::DEFAULT_CHUNK
        }
    );
    assert!(s.is_dynamic());
}

#[test]
fn adaptive_converges_to_per_fingerprint_best() {
    let mix = two_fingerprint_mix();
    let uniform_fp = mix[0].fingerprint();
    let skewed_fp = mix[1].fingerprint();
    assert_ne!(uniform_fp, skewed_fp);
    let want_uniform = proxy_argmin(&problem_offsets(&mix[0]));
    let want_skewed = proxy_argmin(&problem_offsets(&mix[1]));

    let engine = ServeEngine::new(adaptive_cfg(2));
    // Warmup: cold-start prior + forced exploration of all candidates
    // (6 candidates x min_samples 2 = 12 selections per fingerprint; the
    // mix supplies 4 per batch).
    for _ in 0..5 {
        engine.execute_batch(&mix);
    }
    // Post-warmup window.
    let (mut best_hits, mut total, mut exploits, mut adaptive) = (0usize, 0usize, 0u64, 0u64);
    for _ in 0..10 {
        let report = engine.execute_batch(&mix);
        exploits += report.tuner.exploits;
        adaptive += report.tuner.adaptive;
        for (p, &kind) in mix.iter().zip(&report.schedules) {
            let want = if p.fingerprint() == uniform_fp {
                want_uniform
            } else {
                want_skewed
            };
            total += 1;
            if kind == want {
                best_hits += 1;
            }
        }
    }
    let fraction = best_hits as f64 / total as f64;
    assert!(
        fraction >= 0.9,
        "converged to per-fingerprint best for only {:.0}% of {} executions",
        fraction * 100.0,
        total
    );
    assert!(
        exploits as f64 / adaptive as f64 >= 0.9,
        "exploit fraction {exploits}/{adaptive}"
    );
}

#[test]
fn adaptive_checksums_bit_identical_to_fixed_across_thread_counts() {
    let mix = two_fingerprint_mix();
    // Reference: Fixed(ThreadMapped) at 1 thread.
    let reference = ServeEngine::new(fixed_cfg(1, ScheduleKind::ThreadMapped))
        .execute_batch(&mix)
        .checksums;
    for threads in [1usize, 2, 4, 8] {
        for &kind in &CANDIDATES {
            let report = ServeEngine::new(fixed_cfg(threads, kind)).execute_batch(&mix);
            assert_eq!(
                report.checksums, reference,
                "Fixed({kind:?}) at {threads} threads changed numerics"
            );
        }
        let engine = ServeEngine::new(adaptive_cfg(threads));
        for round in 0..12 {
            let report = engine.execute_batch(&mix);
            assert_eq!(
                report.checksums, reference,
                "adaptive at {threads} threads diverged in round {round}"
            );
        }
    }
}

#[test]
fn adaptive_trace_is_deterministic_across_seeds_and_threads() {
    let mix = two_fingerprint_mix();
    let collect_traces = |threads: usize| -> Vec<Vec<ScheduleKind>> {
        let engine = ServeEngine::new(adaptive_cfg(threads));
        (0..10)
            .map(|_| engine.execute_batch(&mix).schedules)
            .collect()
    };
    let base = collect_traces(1);
    assert_eq!(base, collect_traces(1), "same seed must replay the trace");
    assert_eq!(
        base,
        collect_traces(4),
        "thread count must not affect selection"
    );
    // A different seed is allowed to explore differently — but only after
    // the deterministic cold-start + warmup phases.
    let other_engine = ServeEngine::new(adaptive_cfg_seeded(1, SEED + 1));
    let other: Vec<Vec<ScheduleKind>> = (0..10)
        .map(|_| other_engine.execute_batch(&mix).schedules)
        .collect();
    assert_eq!(base[0], other[0], "cold start is seed-independent");
}

#[test]
fn cold_start_uses_shape_prior() {
    let mix = two_fingerprint_mix();
    let engine = ServeEngine::new(adaptive_cfg(1));
    let report = engine.execute_batch(&mix);
    assert_eq!(report.tuner.priors, mix.len() as u64);
    assert_eq!(report.tuner.exploits, 0);
    for (p, &kind) in mix.iter().zip(&report.schedules) {
        assert_eq!(
            kind,
            p.cold_start_prior(PLAN_WORKERS),
            "cold start must use the shape prior"
        );
    }
    // Frontier problems' prior is merge-path (the most skew-tolerant).
    assert!(report
        .schedules
        .iter()
        .all(|&k| k == ScheduleKind::MergePath));
}

#[test]
fn spmv_cold_start_prior_follows_heuristic() {
    use gpulb::sparse::gen;
    // Small regular matrix: §4.5.2 picks thread-mapped; the adaptive
    // engine's first selection must match.
    let problem = Problem::spmv(Arc::new(gen::uniform(100, 100, 4, 2)));
    let engine = ServeEngine::new(adaptive_cfg(1));
    let report = engine.execute_batch(std::slice::from_ref(&problem));
    assert_eq!(report.schedules, vec![problem.static_schedule()]);
    assert_eq!(report.tuner.priors, 1);
}

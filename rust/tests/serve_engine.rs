//! Concurrency smoke tests: the work-stealing pool and the serve engine
//! driven with >= 4 threads under plain `cargo test`, checking that
//! parallel execution is a pure throughput optimization — results and
//! checksums are bit-identical to sequential execution.

use gpulb::serve::{corpus_mix, pool, Problem, ServeConfig, ServeEngine};
use gpulb::sparse::gen;
use std::sync::Arc;

#[test]
fn pool_matches_sequential_map_at_4_threads() {
    let jobs: Vec<u64> = (0..500).collect();
    let (got, stats) = pool::execute(4, &jobs, |&j| j.wrapping_mul(j) ^ 0xABCD);
    let want: Vec<u64> = jobs.iter().map(|&j| j.wrapping_mul(j) ^ 0xABCD).collect();
    assert_eq!(got, want);
    assert_eq!(stats.pops + stats.steals, jobs.len() as u64);
    assert_eq!(stats.threads, 4);
}

#[test]
fn pool_steals_rebalance_skewed_work() {
    // Round-robin seeding puts every heavy job (multiples of 4) on worker 0;
    // the other workers drain their light queues and must steal.
    let jobs: Vec<usize> = (0..64).collect();
    let (got, stats) = pool::execute(4, &jobs, |&i| {
        let iters: u64 = if i % 4 == 0 { 2_000_000 } else { 500 };
        (0..iters).fold(0u64, |acc, x| acc.wrapping_add(x ^ i as u64))
    });
    assert_eq!(got.len(), 64);
    assert_eq!(stats.pops + stats.steals, 64);
    assert!(stats.steals > 0, "expected steals, got {stats:?}");
}

#[test]
fn pool_handles_more_threads_than_jobs() {
    let jobs: Vec<u32> = (0..3).collect();
    let (got, _) = pool::execute(8, &jobs, |&j| j + 1);
    assert_eq!(got, vec![1, 2, 3]);
}

#[test]
fn engine_checksums_invariant_across_thread_counts() {
    let mix = corpus_mix(0);
    assert!(mix.len() >= 10, "smoke mix too small: {}", mix.len());
    let reports: Vec<_> = [1usize, 2, 4, 8]
        .iter()
        .map(|&threads| {
            let engine = ServeEngine::new(ServeConfig::builder().threads(threads).build().unwrap());
            engine.execute_batch(&mix)
        })
        .collect();
    for r in &reports[1..] {
        assert_eq!(
            r.checksums, reports[0].checksums,
            "thread count changed numerics"
        );
    }
}

#[test]
fn engine_reuses_plans_across_batches() {
    let mix = corpus_mix(0);
    let engine = ServeEngine::new(ServeConfig::builder().threads(4).build().unwrap());
    let first = engine.execute_batch(&mix);
    assert!(first.cache.misses > 0);
    let misses_after_first = first.cache.misses;
    let second = engine.execute_batch(&mix);
    assert_eq!(
        second.cache.misses, misses_after_first,
        "second batch should plan nothing"
    );
    assert!(second.cache.hits >= mix.len() as u64);
    assert_eq!(first.checksums, second.checksums);
}

#[test]
fn engine_concurrent_cold_cache_is_consistent() {
    // Many threads racing the same cold cache: duplicates are benign and
    // the cached plans still serve identical results afterwards.
    let problems: Vec<Problem> = (0..24)
        .map(|i| Problem::spmv(Arc::new(gen::power_law(200, 200, 100, 1.4, i))))
        .collect();
    let engine = ServeEngine::new(ServeConfig::builder().threads(8).build().unwrap());
    let cold = engine.execute_batch(&problems);
    let warm = engine.execute_batch(&problems);
    assert_eq!(cold.checksums, warm.checksums);
    assert!(warm.cache.hits >= problems.len() as u64);
}

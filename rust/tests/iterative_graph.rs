//! End-to-end matrix for the engine-driven iterative graph driver:
//! reference parity, bit-identity across thread counts and push/pull
//! switch points, plan-cache warm-up across rounds and re-queries, chaos
//! recovery inside a loop, the arena's zero-steady-state-allocation
//! contract, and the graph bench smoke run.

use std::sync::Arc;

use gpulb::balance::ScheduleKind;
use gpulb::exec::chaos::FaultPlan;
use gpulb::exec::graph;
use gpulb::serve::{
    self, CostFeedback, DirectionPolicy, IterativeDriver, IterativeOptions, LoopReport,
    SchedulePolicy, ServeConfig, ServeEngine,
};
use gpulb::sparse::Csr;

const WORKERS: usize = 64;

fn engine(threads: usize, schedule: ScheduleKind) -> ServeEngine {
    let cfg = ServeConfig::builder()
        .threads(threads)
        .plan_workers(WORKERS)
        .schedule(SchedulePolicy::Fixed(schedule))
        .feedback(CostFeedback::Proxy)
        .build()
        .unwrap();
    ServeEngine::new(cfg)
}

fn smoke_graphs() -> Vec<(&'static str, Arc<Csr>)> {
    serve::iterative_mix(0)
        .into_iter()
        .map(|c| (c.family, c.graph))
        .collect()
}

fn assert_clean(rep: &LoopReport, ctx: &str) {
    assert_eq!(rep.failed_rounds, 0, "{ctx}: rounds exhausted retries");
    assert!(
        rep.rounds.iter().all(|r| r.checksum.is_finite()),
        "{ctx}: non-finite round checksum"
    );
}

#[test]
fn driver_bfs_matches_references_bitwise_across_thread_counts() {
    for (family, g) in smoke_graphs() {
        let reference = graph::bfs_ref(&g, 0);
        let legacy = graph::bfs(&g, 0, ScheduleKind::MergePath, WORKERS);
        assert_eq!(legacy, reference, "{family}: legacy bfs vs queue reference");

        let mut baseline: Option<Vec<u32>> = None;
        for threads in [1, 2, 4, 8] {
            let eng = engine(threads, ScheduleKind::MergePath);
            let mut driver = IterativeDriver::new(&eng, Arc::clone(&g));
            let (depth, rep) = driver.bfs(0);
            assert_clean(&rep, &format!("{family} bfs threads={threads}"));
            assert_eq!(depth, reference, "{family} bfs threads={threads}");
            match &baseline {
                None => baseline = Some(depth),
                Some(b) => assert_eq!(&depth, b, "{family} bfs thread-variant"),
            }
        }
    }
}

#[test]
fn driver_sssp_matches_references_bitwise_across_thread_counts() {
    for (family, g) in smoke_graphs() {
        let legacy = graph::sssp(&g, 0, ScheduleKind::MergePath, WORKERS);
        let dijkstra = graph::sssp_ref(&g, 0);
        for (v, (a, b)) in legacy.iter().zip(&dijkstra).enumerate() {
            assert!(
                (a - b).abs() < 1e-9 || (a.is_infinite() && b.is_infinite()),
                "{family}: legacy sssp vs Dijkstra at vertex {v}: {a} vs {b}"
            );
        }

        let mut baseline: Option<Vec<u64>> = None;
        for threads in [1, 2, 4, 8] {
            let eng = engine(threads, ScheduleKind::MergePath);
            let mut driver = IterativeDriver::new(&eng, Arc::clone(&g));
            let (dist, rep) = driver.sssp(0);
            assert_clean(&rep, &format!("{family} sssp threads={threads}"));
            let bits: Vec<u64> = dist.iter().map(|d| d.to_bits()).collect();
            let legacy_bits: Vec<u64> = legacy.iter().map(|d| d.to_bits()).collect();
            assert_eq!(bits, legacy_bits, "{family} sssp threads={threads} vs legacy");
            match &baseline {
                None => baseline = Some(bits),
                Some(b) => assert_eq!(&bits, b, "{family} sssp thread-variant"),
            }
        }
    }
}

#[test]
fn driver_pagerank_matches_legacy_bitwise() {
    for (family, g) in smoke_graphs() {
        let (legacy, legacy_iters) =
            graph::pagerank(&g, ScheduleKind::MergePath, WORKERS, 0.85, 1e-10, 60);
        for threads in [1, 4] {
            let eng = engine(threads, ScheduleKind::MergePath);
            let mut driver = IterativeDriver::new(&eng, Arc::clone(&g));
            let (rank, iters, rep) = driver.pagerank(0.85, 1e-10, 60);
            assert_clean(&rep, &format!("{family} pagerank threads={threads}"));
            assert_eq!(iters, legacy_iters, "{family} pagerank iteration count");
            let bits: Vec<u64> = rank.iter().map(|r| r.to_bits()).collect();
            let legacy_bits: Vec<u64> = legacy.iter().map(|r| r.to_bits()).collect();
            assert_eq!(bits, legacy_bits, "{family} pagerank threads={threads}");
        }
    }
}

#[test]
fn direction_optimizing_bfs_is_bit_identical_to_push_only() {
    for (family, g) in smoke_graphs() {
        let eng = engine(2, ScheduleKind::MergePath);
        let mut push_driver = IterativeDriver::with_options(
            &eng,
            Arc::clone(&g),
            IterativeOptions {
                direction: DirectionPolicy::PushOnly,
                faults: None,
            },
        );
        let (push_depth, push_rep) = push_driver.bfs(0);
        assert_clean(&push_rep, &format!("{family} push-only"));
        assert_eq!(push_rep.pull_rounds, 0);

        // The default heuristic, plus an aggressive switch point that
        // forces pull as early as possible: the switch point must never
        // change the answer.
        for (name, policy) in [
            ("default", DirectionPolicy::default()),
            (
                "aggressive",
                DirectionPolicy::Adaptive { alpha: 1, beta: 1 },
            ),
        ] {
            let mut driver = IterativeDriver::with_options(
                &eng,
                Arc::clone(&g),
                IterativeOptions {
                    direction: policy,
                    faults: None,
                },
            );
            let (depth, rep) = driver.bfs(0);
            assert_clean(&rep, &format!("{family} {name}"));
            assert_eq!(depth, push_depth, "{family} {name}: push/pull changed depths");
        }

        // Both families take tail pull rounds under the default heuristic
        // (the alpha check trips once `unexplored` shrinks), and the
        // driver's realized direction trace must match the virtual-time
        // simulation round for round.
        let sim = serve::simulate_iterative(&g, 0, 1, DirectionPolicy::default());
        let mut driver = IterativeDriver::new(&eng, Arc::clone(&g));
        let (_, rep) = driver.bfs(0);
        assert_eq!(
            rep.rounds.len(),
            sim.rounds.len(),
            "{family}: driver round count vs simulation"
        );
        assert_eq!(
            rep.pull_rounds, sim.pull_rounds,
            "{family}: driver pull rounds vs simulation"
        );
        assert!(
            rep.pull_rounds >= 1,
            "{family}: default heuristic never switched to pull"
        );
    }
}

#[test]
fn plan_cache_warms_within_and_across_queries() {
    let (_, g) = smoke_graphs().remove(0);

    // PageRank submits the same fingerprint every round: the cache must
    // hit from round 2 onward within a single query on a cold engine.
    let eng = engine(2, ScheduleKind::MergePath);
    let mut driver = IterativeDriver::new(&eng, Arc::clone(&g));
    let (_, iters, rep) = driver.pagerank(0.85, 1e-10, 20);
    assert!(iters >= 3, "need a few rounds to observe warm-up");
    assert!(
        rep.rounds[1].cache_hits > rep.rounds[0].cache_hits,
        "pagerank round 2 missed the plan cache"
    );
    let last = rep.rounds.last().unwrap();
    assert!(
        last.cache_hits - rep.rounds[0].cache_hits >= (rep.rounds.len() - 1) as u64,
        "every pagerank round after the first should hit"
    );

    // A repeated BFS query replays the same frontier fingerprints: every
    // round of the second traversal hits the plan warmed by the first.
    let eng = engine(2, ScheduleKind::MergePath);
    let mut driver = IterativeDriver::new(&eng, Arc::clone(&g));
    let (_, first) = driver.bfs(0);
    let (_, second) = driver.bfs(0);
    assert_eq!(first.rounds.len(), second.rounds.len());
    assert!(
        second.cache.hits - first.cache.hits >= second.rounds.len() as u64,
        "re-query rounds should all hit the plan cache: first {:?}, second {:?}",
        first.cache,
        second.cache
    );
}

#[test]
fn chaos_rounds_recover_bit_identically() {
    let (_, g) = smoke_graphs().remove(0);
    // ThreadMapped is the conservative fallback the retry ladder re-plans
    // onto, so recovered rounds reduce bit-identically to clean ones.
    let clean_engine = engine(2, ScheduleKind::ThreadMapped);
    let mut clean = IterativeDriver::new(&clean_engine, Arc::clone(&g));
    let (clean_depth, clean_rep) = clean.bfs(0);
    assert_clean(&clean_rep, "clean bfs");

    let chaos_engine = engine(2, ScheduleKind::ThreadMapped);
    let mut chaotic = IterativeDriver::with_options(
        &chaos_engine,
        Arc::clone(&g),
        IterativeOptions {
            direction: DirectionPolicy::default(),
            faults: Some(FaultPlan::new(7, 1.0)),
        },
    );
    let (depth, rep) = chaotic.bfs(0);
    assert_eq!(rep.failed_rounds, 0, "a faulted round exhausted its retries");
    assert!(rep.recovered_faults > 0, "rate-1.0 plan injected no faults");
    assert_eq!(depth, clean_depth, "recovered traversal changed depths");
    assert_eq!(rep.rounds.len(), clean_rep.rounds.len());
    for (a, b) in rep.rounds.iter().zip(&clean_rep.rounds) {
        assert_eq!(
            a.checksum.to_bits(),
            b.checksum.to_bits(),
            "round {} recovered to a different checksum",
            a.round
        );
    }
}

#[test]
fn arena_steady_state_allocates_nothing() {
    let (_, g) = smoke_graphs().remove(0);
    let eng = engine(2, ScheduleKind::MergePath);
    let mut driver = IterativeDriver::new(&eng, Arc::clone(&g));

    // Warm-up query, then capture the arena's capacity profile.
    let (_, warm) = driver.bfs(0);
    assert_clean(&warm, "warm-up bfs");
    let warm_stats = warm.arena;
    assert_eq!(warm_stats.reallocations, 0, "warm-up allocated mid-loop");
    assert_eq!(
        warm_stats.recycled_rounds, warm_stats.rounds,
        "engine retained kernel buffers past the batch"
    );

    // Steady state: more traversals of every algorithm reuse the same
    // buffers — capacities frozen, zero reallocations, every round's
    // kernel buffers recycled.
    let (_, _) = driver.bfs(0);
    let (_, _) = driver.sssp(0);
    let (_, _, rep) = driver.pagerank(0.85, 1e-10, 10);
    let stats = rep.arena;
    assert_eq!(stats.reallocations, 0, "steady-state rounds allocated");
    assert_eq!(stats.recycled_rounds, stats.rounds);
    assert!(stats.rounds > warm_stats.rounds);
    assert_eq!(stats.frontier_capacity, warm_stats.frontier_capacity);
    assert_eq!(stats.pull_capacity, warm_stats.pull_capacity);
    assert_eq!(stats.offsets_capacity, warm_stats.offsets_capacity);
    assert_eq!(stats.bitmap_words, warm_stats.bitmap_words);
}

#[test]
fn graph_bench_smoke_writes_artifact_and_meets_floor() {
    let out = std::env::temp_dir().join(format!("BENCH_graph_smoke_{}.json", std::process::id()));
    let out = out.to_str().unwrap().to_owned();
    let speedup = serve::run_graph_bench(0, 1.0, &out).expect("smoke bench");
    assert!(speedup >= 1.0);
    let json = std::fs::read_to_string(&out).expect("bench artifact written");
    for family in ["rmat_naive", "rmat_engine", "road_naive", "road_engine"] {
        assert!(json.contains(family), "artifact missing family {family}");
    }
    assert!(json.contains("\"better\": \"lower\""));
    assert!(json.contains("virtual-steps"));
    let _ = std::fs::remove_file(&out);
}

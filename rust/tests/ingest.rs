//! Ingest front-end acceptance: seeded traces replay bit-identically,
//! checksums through the micro-batching path match direct
//! `execute_batch` runs, priority classes drain in order, and the
//! threaded `IngestServer` delivers the same results as the
//! deterministic virtual-clock driver.

use std::sync::Arc;
use std::time::Duration;

use gpulb::prelude::*;
use gpulb::serve::ingest::{run_trace, IngestServer};
use gpulb::serve::{bursty_trace, ingest_gate_catalog, poisson_trace, Arrival};

/// The CI gate configuration: fixed merge-path + proxy feedback makes
/// every latency a pure function of (catalog, trace, window).
fn gate_engine(threads: usize) -> Engine {
    Engine::new(
        ServeConfig::builder()
            .threads(threads)
            .plan_workers(256)
            .schedule(SchedulePolicy::Fixed(ScheduleKind::MergePath))
            .feedback(CostFeedback::Proxy)
            .build()
            .unwrap(),
    )
}

#[test]
fn same_seed_replays_cuts_latencies_and_checksums_bitwise() {
    let catalog = ingest_gate_catalog(0);
    let arrivals = poisson_trace(catalog.len(), 64, 2000.0, 0xFEED);
    let cfg = IngestConfig::builder().max_batch(4).build().unwrap();
    let a = run_trace(&gate_engine(2), &catalog, &arrivals, &cfg).unwrap();
    let b = run_trace(&gate_engine(2), &catalog, &arrivals, &cfg).unwrap();
    // The virtual clock must also be independent of host thread count.
    let c = run_trace(&gate_engine(1), &catalog, &arrivals, &cfg).unwrap();
    assert_eq!(a.requests, 64);
    assert_eq!(a.batches, b.batches);
    for other in [&b, &c] {
        assert_eq!(a.batches, other.batches);
        for (ra, rb) in a.records.iter().zip(&other.records) {
            assert_eq!(ra.index, rb.index);
            assert_eq!(ra.class, rb.class);
            assert_eq!(ra.arrived.to_bits(), rb.arrived.to_bits());
            assert_eq!(ra.cut.to_bits(), rb.cut.to_bits());
            assert_eq!(ra.done.to_bits(), rb.done.to_bits());
            assert_eq!(ra.checksum.to_bits(), rb.checksum.to_bits());
        }
        assert_eq!(a.p50.to_bits(), other.p50.to_bits());
        assert_eq!(a.p95.to_bits(), other.p95.to_bits());
        assert_eq!(a.p99.to_bits(), other.p99.to_bits());
        assert_eq!(a.sustained_rps.to_bits(), other.sustained_rps.to_bits());
    }
    // A different seed produces a genuinely different trace.
    let other = poisson_trace(catalog.len(), 64, 2000.0, 0xBEEF);
    assert_ne!(arrivals, other);
}

#[test]
fn ingest_checksums_match_direct_execute_batch() {
    let catalog = ingest_gate_catalog(0);
    let direct = gate_engine(2).execute_batch(&catalog).checksums;
    let arrivals = bursty_trace(catalog.len(), 48, 3000.0, 8, 7);
    let cfg = IngestConfig::builder().build().unwrap();
    let report = run_trace(&gate_engine(2), &catalog, &arrivals, &cfg).unwrap();
    assert_eq!(report.requests, arrivals.len());
    // Records come back in trace order; each request's checksum must be
    // bit-identical to its catalog problem run straight through the
    // engine — the front-end adds batching, never numerics.
    for (rec, arr) in report.records.iter().zip(&arrivals) {
        assert_eq!(
            rec.checksum.to_bits(),
            direct[arr.problem].to_bits(),
            "request {} (problem {}) diverged from direct execution",
            rec.index,
            arr.problem
        );
    }
}

#[test]
fn interactive_requests_drain_before_bulk_within_a_batch() {
    let catalog = ingest_gate_catalog(0);
    let arrivals = vec![
        Arrival {
            at: 0.0,
            class: IngestClass::Bulk,
            problem: 0,
        },
        Arrival {
            at: 1e-4,
            class: IngestClass::Interactive,
            problem: 1,
        },
    ];
    let cfg = IngestConfig::builder().max_batch(2).build().unwrap();
    let report = run_trace(&gate_engine(1), &catalog, &arrivals, &cfg).unwrap();
    assert_eq!(report.batches, 1, "both arrivals share one micro-batch");
    let bulk = &report.records[0];
    let interactive = &report.records[1];
    assert_eq!(bulk.class, IngestClass::Bulk);
    assert_eq!(interactive.class, IngestClass::Interactive);
    // Same cut, but the interactive request completes first despite
    // arriving second: priority ordering inside the batch.
    assert_eq!(bulk.cut.to_bits(), interactive.cut.to_bits());
    assert!(
        interactive.done < bulk.done,
        "interactive ({}) must drain before bulk ({})",
        interactive.done,
        bulk.done
    );
}

#[test]
fn report_accounts_every_request_per_class() {
    let catalog = ingest_gate_catalog(0);
    let arrivals = poisson_trace(catalog.len(), 200, 5000.0, 42);
    let cfg = IngestConfig::builder().max_batch(8).build().unwrap();
    let report = run_trace(&gate_engine(2), &catalog, &arrivals, &cfg).unwrap();
    assert_eq!(report.requests, 200);
    let class_total: usize = report.classes.iter().map(|c| c.requests).sum();
    assert_eq!(class_total, 200, "class summaries must cover every request");
    for c in &report.classes {
        assert!((0.0..=1.0).contains(&c.slo_violations), "{:?}", c.class);
        assert!(c.p50 <= c.p95 && c.p95 <= c.p99, "{:?}", c.class);
        assert!(c.p50 >= 0.0);
    }
    assert!(report.sustained_rps > 0.0);
    assert!(report.makespan > 0.0);
    assert!(report.mean_batch() >= 1.0 && report.mean_batch() <= 8.0);
}

#[test]
fn threaded_server_delivers_direct_execution_results() {
    let catalog = ingest_gate_catalog(0);
    let direct = gate_engine(2).execute_batch(&catalog).checksums;
    let server = IngestServer::start(
        Arc::new(gate_engine(2)),
        IngestConfig::builder()
            .max_batch(4)
            .max_wait(Duration::from_millis(5))
            .build()
            .unwrap(),
    );
    let handle = server.handle();
    let tickets: Vec<_> = catalog
        .iter()
        .enumerate()
        .map(|(i, p)| (i, handle.submit(p.clone(), IngestClass::Standard).unwrap()))
        .collect();
    drop(handle);
    for (i, ticket) in tickets {
        let completion = ticket.wait().unwrap();
        assert!(completion.latency >= 0.0);
        assert_eq!(
            completion.checksum.to_bits(),
            direct[i].to_bits(),
            "problem {i} diverged through the threaded front-end"
        );
    }
    let report = server.finish().unwrap();
    assert_eq!(report.requests, catalog.len());
    assert!(report.batches >= 1);
    assert!(report.records.iter().all(|r| r.done >= r.cut));
}

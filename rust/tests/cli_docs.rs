//! The serve-family flag surface has one canonical order,
//! `gpulb::cli::SERVE_FLAG_ORDER`: `main.rs` pins its `SERVE_SPEC` table
//! (and therefore `serve --help`) against it, and this test pins the
//! README's serve-flags list — so the two user-facing renderings can
//! never drift apart or silently drop a flag.

use gpulb::cli::SERVE_FLAG_ORDER;

#[test]
fn readme_serve_flags_match_the_canonical_order() {
    let readme = include_str!("../../README.md");
    let begin = readme
        .find("<!-- serve-flags:begin -->")
        .expect("README lost the serve-flags:begin marker");
    let end = readme
        .find("<!-- serve-flags:end -->")
        .expect("README lost the serve-flags:end marker");
    assert!(begin < end, "serve-flags markers out of order");

    let mut listed = Vec::new();
    for line in readme[begin..end].lines() {
        if let Some(rest) = line.trim_start().strip_prefix("- `--") {
            let name: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '-')
                .collect();
            listed.push(name);
        }
    }
    let listed: Vec<&str> = listed.iter().map(String::as_str).collect();
    assert_eq!(
        listed, SERVE_FLAG_ORDER,
        "README serve-flags list diverged from cli::SERVE_FLAG_ORDER \
         (every serve flag, in canonical order, exactly once)"
    );
}

#[test]
fn canonical_order_has_no_duplicates() {
    let mut seen = std::collections::BTreeSet::new();
    for name in SERVE_FLAG_ORDER {
        assert!(seen.insert(name), "duplicate serve flag `{name}`");
    }
}

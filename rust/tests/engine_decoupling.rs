//! The acceptance grep: engine code reaches work processing only through
//! the `WorkKernel` trait.  `serve/batch.rs` and `serve/mod.rs` (and the
//! other engine modules) must contain no per-workload-kind execution,
//! reduction, or proxy match arms — only the trait's dispatch points.
//!
//! The check is textual on purpose: it pins the *source* of the engine,
//! so a future PR that reintroduces a `match problem { Spmv => … }` arm
//! or calls an executor function directly fails loudly here even if it
//! compiles and computes correctly.

const ENGINE_SOURCES: [(&str, &str); 5] = [
    ("serve/mod.rs", include_str!("../src/serve/mod.rs")),
    ("serve/batch.rs", include_str!("../src/serve/batch.rs")),
    ("serve/plan_cache.rs", include_str!("../src/serve/plan_cache.rs")),
    ("serve/pool.rs", include_str!("../src/serve/pool.rs")),
    ("serve/tuner.rs", include_str!("../src/serve/tuner.rs")),
];

/// Strings that would indicate the engine special-casing one workload's
/// execution, reduction, or proxy path.  Constructors over boxed kernels
/// (`SpmvKernel::new` etc. in `Problem`'s builders) are allowed — they are
/// the thin constructor layer — so kernel *type* names are not forbidden;
/// executor entry points and per-kind variant matching are.
const FORBIDDEN: [&str; 16] = [
    // Direct executor-module calls.
    "exec::spmv",
    "exec::gemm::",
    "exec::graph",
    "exec::spgemm::",
    "exec::spmm::",
    "spmv::execute",
    "gemm::execute",
    "spgemm::execute",
    "spmm::execute",
    "execute_stream_host",
    "execute_macs",
    "mac_shard_partials",
    "frontier_shard",
    "apply_partials",
    // The pre-trait per-kind shard enum and Problem variants.
    "ShardPartials",
    "Problem::Spmv",
];

#[test]
fn engine_has_no_per_kind_execution_arms() {
    for (path, src) in ENGINE_SOURCES {
        for needle in FORBIDDEN {
            assert!(
                !src.contains(needle),
                "{path} contains `{needle}`: engine code must reach work \
                 processing only through the WorkKernel trait"
            );
        }
    }
}

#[test]
fn engine_dispatches_through_the_kernel_trait() {
    // The positive half: the dispatch surface exists and is the trait.
    let batch = ENGINE_SOURCES[1].1;
    assert!(
        batch.contains("dyn DynKernel"),
        "serve/batch.rs must hold problems as boxed WorkKernel trait objects"
    );
    let requires = |call: &str| {
        assert!(
            batch.contains(call),
            "serve/batch.rs must dispatch `{call}` through the kernel trait"
        );
    };
    requires("execute_stream");
    requires("execute_assignment");
    requires("shard_dyn");
    requires("reduce_dyn");
    // And the engine proper never names a workload at all.
    let engine = ENGINE_SOURCES[0].1;
    for kind in ["SpmvKernel", "GemmKernel", "FrontierKernel"] {
        assert!(
            !engine.contains(kind),
            "serve/mod.rs mentions `{kind}`: the engine must be workload-agnostic"
        );
    }
}

//! The acceptance grep: engine code reaches work processing only through
//! the `WorkKernel` trait.  `serve/batch.rs` and `serve/mod.rs` (and the
//! other engine modules) must contain no per-workload-kind execution,
//! reduction, or proxy match arms — only the trait's dispatch points.
//!
//! The check is textual on purpose: it pins the *source* of the engine,
//! so a future PR that reintroduces a `match problem { Spmv => … }` arm
//! or calls an executor function directly fails loudly here even if it
//! compiles and computes correctly.

const ENGINE_SOURCES: [(&str, &str); 8] = [
    ("serve/mod.rs", include_str!("../src/serve/mod.rs")),
    ("serve/batch.rs", include_str!("../src/serve/batch.rs")),
    ("serve/cluster.rs", include_str!("../src/serve/cluster.rs")),
    ("serve/config.rs", include_str!("../src/serve/config.rs")),
    ("serve/ingest.rs", include_str!("../src/serve/ingest.rs")),
    ("serve/plan_cache.rs", include_str!("../src/serve/plan_cache.rs")),
    ("serve/pool.rs", include_str!("../src/serve/pool.rs")),
    ("serve/tuner.rs", include_str!("../src/serve/tuner.rs")),
];

/// Strings that would indicate the engine special-casing one workload's
/// execution, reduction, or proxy path.  Constructors over boxed kernels
/// (`SpmvKernel::new` etc. in `Problem`'s builders) are allowed — they are
/// the thin constructor layer — so kernel *type* names are not forbidden;
/// executor entry points and per-kind variant matching are.
const FORBIDDEN: [&str; 16] = [
    // Direct executor-module calls.
    "exec::spmv",
    "exec::gemm::",
    "exec::graph",
    "exec::spgemm::",
    "exec::spmm::",
    "spmv::execute",
    "gemm::execute",
    "spgemm::execute",
    "spmm::execute",
    "execute_stream_host",
    "execute_macs",
    "mac_shard_partials",
    "frontier_shard",
    "apply_partials",
    // The pre-trait per-kind shard enum and Problem variants.
    "ShardPartials",
    "Problem::Spmv",
];

#[test]
fn engine_has_no_per_kind_execution_arms() {
    for (path, src) in ENGINE_SOURCES {
        for needle in FORBIDDEN {
            assert!(
                !src.contains(needle),
                "{path} contains `{needle}`: engine code must reach work \
                 processing only through the WorkKernel trait"
            );
        }
    }
}

/// Everything that configures an engine, outside `serve/config.rs` (the
/// one module allowed to name the struct's fields): the serve sources,
/// the CLI binary, the bench harness, and every engine-driving test.
const BUILDER_ONLY_SOURCES: [(&str, &str); 19] = [
    ("serve/mod.rs", include_str!("../src/serve/mod.rs")),
    ("serve/batch.rs", include_str!("../src/serve/batch.rs")),
    ("serve/cluster.rs", include_str!("../src/serve/cluster.rs")),
    ("serve/ingest.rs", include_str!("../src/serve/ingest.rs")),
    ("serve/iterative.rs", include_str!("../src/serve/iterative.rs")),
    ("serve/mix.rs", include_str!("../src/serve/mix.rs")),
    ("serve/landscape.rs", include_str!("../src/serve/landscape.rs")),
    ("src/main.rs", include_str!("../src/main.rs")),
    (
        "benches/serve_throughput.rs",
        include_str!("../benches/serve_throughput.rs"),
    ),
    ("tests/serve_engine.rs", include_str!("serve_engine.rs")),
    ("tests/serve_adaptive.rs", include_str!("serve_adaptive.rs")),
    ("tests/kernel_shards.rs", include_str!("kernel_shards.rs")),
    ("tests/stream_schedules.rs", include_str!("stream_schedules.rs")),
    ("tests/dynamic_schedules.rs", include_str!("dynamic_schedules.rs")),
    ("tests/serve_plan_cache.rs", include_str!("serve_plan_cache.rs")),
    ("tests/ingest.rs", include_str!("ingest.rs")),
    ("tests/fault_tolerance.rs", include_str!("fault_tolerance.rs")),
    ("tests/cluster.rs", include_str!("cluster.rs")),
    ("tests/iterative_graph.rs", include_str!("iterative_graph.rs")),
];

#[test]
fn serve_config_is_constructed_only_through_the_builder() {
    // The builder's `build()` is the single validation point for the
    // engine knobs; a struct literal (or `Default::default()`) would
    // bypass it and quietly reintroduce the old scattered `max(1)`
    // clamps.  Return-type positions (`-> ServeConfig {`) are fine.
    for (path, src) in BUILDER_ONLY_SOURCES {
        assert!(
            !src.contains("ServeConfig::default()"),
            "{path} calls ServeConfig::default(); construct through \
             ServeConfig::builder() so the knobs are validated"
        );
        let mut from = 0;
        while let Some(pos) = src[from..].find("ServeConfig {") {
            let at = from + pos;
            let before = &src[..at];
            let before = before.strip_suffix('&').unwrap_or(before);
            assert!(
                before.ends_with("-> "),
                "{path} builds a ServeConfig struct literal (byte {at}); \
                 construct through ServeConfig::builder()"
            );
            from = at + 1;
        }
    }
}

#[test]
fn engine_dispatches_through_the_kernel_trait() {
    // The positive half: the dispatch surface exists and is the trait.
    let batch = ENGINE_SOURCES[1].1;
    assert!(
        batch.contains("dyn DynKernel"),
        "serve/batch.rs must hold problems as boxed WorkKernel trait objects"
    );
    let requires = |call: &str| {
        assert!(
            batch.contains(call),
            "serve/batch.rs must dispatch `{call}` through the kernel trait"
        );
    };
    requires("execute_stream");
    requires("execute_assignment");
    requires("shard_dyn");
    requires("reduce_dyn");
    // And the engine proper never names a workload at all.
    let engine = ENGINE_SOURCES[0].1;
    for kind in ["SpmvKernel", "GemmKernel", "FrontierKernel"] {
        assert!(
            !engine.contains(kind),
            "serve/mod.rs mentions `{kind}`: the engine must be workload-agnostic"
        );
    }
}

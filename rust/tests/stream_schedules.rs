//! The zero-materialization invariants, end to end:
//!
//! * **Stream/materialized equivalence** — for every streaming-capable
//!   schedule and every source shape (uniform, power-law, empty-row-heavy,
//!   single giant row, degenerate empties), the lazy per-worker
//!   `worker_segments` streams concatenate to exactly the materialized
//!   `Assignment`'s segments, and cover the atom set exactly.
//! * **Intra-problem parallel execution** — splitting a problem into
//!   worker-range shards across the serve pool is checksum-**bit**-identical
//!   to sequential whole-problem execution at 1/2/4/8 threads, for SpMV,
//!   GEMM (Stream-K tile fixup), and frontier problems.

use std::sync::Arc;

use gpulb::balance::stream::{self, ScheduleDescriptor};
use gpulb::balance::{OffsetsSource, ScheduleKind};
use gpulb::rng::Rng;
use gpulb::serve::{CostFeedback, Problem, SchedulePolicy, ServeConfig, ServeEngine};
use gpulb::sparse::gen;
use gpulb::streamk::{Blocking, GemmShape};

const STREAMING: [ScheduleKind; 5] = [
    ScheduleKind::ThreadMapped,
    ScheduleKind::GroupMapped(32),
    ScheduleKind::GroupMapped(128),
    ScheduleKind::MergePath,
    ScheduleKind::NonzeroSplit,
];

/// The named source-shape corpus of the equivalence property.
fn shaped_sources() -> Vec<(&'static str, Vec<usize>)> {
    let mut out: Vec<(&'static str, Vec<usize>)> = vec![
        ("degenerate-empty", vec![0]),
        ("all-empty-rows", vec![0, 0, 0, 0, 0]),
        ("single-giant-row", vec![0, 10_000]),
        ("single-atom", vec![0, 1]),
    ];
    out.push(("uniform", gen::uniform(257, 257, 8, 11).offsets));
    out.push(("power-law", gen::power_law(300, 300, 150, 1.6, 7).offsets));
    let lens: Vec<usize> = (0..96).map(|i| if i % 3 == 0 { 5 } else { 0 }).collect();
    out.push(("empty-row-mix", gpulb::balance::prefix::exclusive(&lens)));
    out
}

#[test]
fn streams_concatenate_to_materialized_assignment() {
    for (name, offsets) in shaped_sources() {
        let src = OffsetsSource::new(&offsets);
        for kind in STREAMING {
            for workers in [1usize, 2, 7, 64, 500] {
                let desc = kind
                    .descriptor(&src, workers)
                    .expect("streaming schedule has a descriptor");
                let asg = kind.assign(&src, workers);
                assert_eq!(
                    desc.workers(),
                    asg.workers.len(),
                    "{name} {kind:?} x{workers}: worker count"
                );
                for (w, wa) in asg.workers.iter().enumerate() {
                    let streamed: Vec<_> = stream::worker_segments(desc, &offsets, w).collect();
                    assert_eq!(
                        streamed, wa.segments,
                        "{name} {kind:?} x{workers} worker {w}: segments"
                    );
                    assert_eq!(desc.granularity(), wa.granularity);
                }
                asg.validate(&src)
                    .unwrap_or_else(|e| panic!("{name} {kind:?} x{workers}: {e:#}"));
            }
        }
    }
}

#[test]
fn prop_streams_cover_random_sources_exactly() {
    let mut rng = Rng::new(0x57AE_A11);
    for case in 0..40 {
        let tiles = rng.below(50);
        let mut offsets = Vec::with_capacity(tiles + 1);
        offsets.push(0usize);
        for _ in 0..tiles {
            let len = match rng.below(8) {
                0..=2 => 0,
                3..=5 => rng.range(1, 10),
                6 => rng.range(10, 100),
                _ => rng.range(100, 2000),
            };
            offsets.push(offsets.last().unwrap() + len);
        }
        let src = OffsetsSource::new(&offsets);
        let workers = 1 + rng.below(200);
        for kind in STREAMING {
            let desc = kind.descriptor(&src, workers).unwrap();
            let mut covered = vec![false; *offsets.last().unwrap()];
            stream::for_each_segment(desc, &offsets, |s| {
                let t = s.tile as usize;
                assert!(
                    s.atom_begin >= offsets[t] && s.atom_end <= offsets[t + 1],
                    "case {case} {kind:?}: segment out of tile bounds"
                );
                for a in s.atom_begin..s.atom_end {
                    assert!(!covered[a], "case {case} {kind:?}: atom {a} twice");
                    covered[a] = true;
                }
            });
            assert!(
                covered.iter().all(|&c| c),
                "case {case} {kind:?} x{workers}: atoms uncovered"
            );
        }
    }
}

/// A heterogeneous mix exercising all three partial kinds (scalar SpMV,
/// scalar frontier, tile-accumulator GEMM).
fn split_mix() -> Vec<Problem> {
    let graph = Arc::new(gen::uniform(128, 128, 4, 9));
    let frontier: Vec<u32> = (0..graph.rows as u32).collect();
    vec![
        Problem::spmv(Arc::new(gen::power_law(400, 400, 200, 1.5, 3))),
        Problem::spmv(Arc::new(gen::uniform(256, 256, 8, 4))),
        Problem::gemm(GemmShape::new(96, 80, 72), Blocking::new(32, 32, 16), 7),
        Problem::frontier(graph, frontier),
    ]
}

fn cfg(threads: usize, kind: ScheduleKind, split_min_atoms: usize) -> ServeConfig {
    ServeConfig::builder()
        .threads(threads)
        .plan_workers(64)
        .schedule(SchedulePolicy::Fixed(kind))
        .feedback(CostFeedback::Proxy)
        .split_min_atoms(split_min_atoms)
        .build()
        .unwrap()
}

#[test]
fn sharded_execution_checksum_bit_identical_across_thread_counts() {
    let mix = split_mix();
    for kind in [
        ScheduleKind::ThreadMapped,
        ScheduleKind::GroupMapped(32),
        ScheduleKind::MergePath,
        ScheduleKind::NonzeroSplit,
    ] {
        // Reference: sequential, splitting disabled.
        let reference = ServeEngine::new(cfg(1, kind, usize::MAX))
            .execute_batch(&mix)
            .checksums;
        for threads in [1usize, 2, 4, 8] {
            // Threshold 1 forces the two-phase path for every problem
            // (at >1 thread); the fixup must reproduce the sequential
            // accumulation order bit for bit.
            let report = ServeEngine::new(cfg(threads, kind, 1)).execute_batch(&mix);
            assert_eq!(
                report.checksums, reference,
                "{kind:?} at {threads} threads diverged from sequential"
            );
            if threads > 1 {
                assert_eq!(
                    report.split_problems,
                    mix.len(),
                    "{kind:?} at {threads} threads: expected every problem split"
                );
            }
        }
    }
}

#[test]
fn split_threshold_gates_sharding() {
    let mix = split_mix();
    let report = ServeEngine::new(cfg(4, ScheduleKind::MergePath, usize::MAX)).execute_batch(&mix);
    assert_eq!((report.split_problems, report.shards), (0, 0));
    let report = ServeEngine::new(cfg(4, ScheduleKind::MergePath, 1)).execute_batch(&mix);
    assert_eq!(report.split_problems, mix.len());
    assert!(report.shards > mix.len(), "shards: {}", report.shards);
}

#[test]
fn binning_problems_never_split_but_stay_correct() {
    // Binning has no streaming descriptor: the engine must batch such
    // problems whole even below the split threshold, with identical
    // checksums at any thread count.
    let mix = split_mix();
    let reference = ServeEngine::new(cfg(1, ScheduleKind::Binning, usize::MAX))
        .execute_batch(&mix)
        .checksums;
    for threads in [2usize, 8] {
        let report = ServeEngine::new(cfg(threads, ScheduleKind::Binning, 1)).execute_batch(&mix);
        assert_eq!((report.split_problems, report.shards), (0, 0));
        assert_eq!(report.checksums, reference);
    }
}

#[test]
fn sharded_proxy_feedback_matches_whole_problem_proxy() {
    // Proxy cost is a pure function of (offsets, schedule, workers):
    // splitting must not change the cost the tuner sees, or traces would
    // diverge across thread counts.
    let mix = split_mix();
    let whole = ServeEngine::new(cfg(1, ScheduleKind::MergePath, usize::MAX));
    let split = ServeEngine::new(cfg(4, ScheduleKind::MergePath, 1));
    let _ = whole.execute_batch(&mix);
    let _ = split.execute_batch(&mix);
    // Descriptor streams are deterministic, so re-running either engine
    // reproduces its checksums exactly.
    assert_eq!(
        whole.execute_batch(&mix).checksums,
        split.execute_batch(&mix).checksums
    );
}

#[test]
fn descriptor_small_enough_for_copy_semantics() {
    assert!(std::mem::size_of::<ScheduleDescriptor>() <= 32);
}

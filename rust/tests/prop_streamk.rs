//! Property tests over the Stream-K decompositions (seeded randomized
//! shapes; failures reproduce from the printed case index).
//!
//! Invariants:
//! * every decomposition covers each tile's iteration space exactly once;
//! * basic Stream-K's iteration imbalance is <= 1;
//! * Stream-K generalizes to data-parallel (g == tiles) and fixed-split
//!   (g == s * tiles) in per-CTA iteration counts;
//! * host numerics of every decomposition equal the reference GEMM;
//! * grid-size model consistency: ItersPerCta * g covers total iters.

use gpulb::exec::dense::DenseMat;
use gpulb::exec::gemm;
use gpulb::rng::Rng;
use gpulb::sim::gpu::{GpuSpec, Precision};
use gpulb::streamk::{decomp, model, Blocking, Decomposition, GemmShape};

const CASES: usize = 80;

fn random_shape(rng: &mut Rng) -> GemmShape {
    GemmShape::new(
        rng.range(1, 1500),
        rng.range(1, 1500),
        rng.range(1, 8000),
    )
}

fn random_blocking(rng: &mut Rng) -> Blocking {
    let opts = [
        Blocking::new(128, 128, 32),
        Blocking::new(64, 64, 16),
        Blocking::new(32, 64, 8),
        Blocking::new(16, 16, 4),
    ];
    opts[rng.below(opts.len())]
}

#[test]
fn prop_all_decompositions_cover_exactly() {
    let mut rng = Rng::new(0x51EE);
    for case in 0..CASES {
        let shape = random_shape(&mut rng);
        let blk = random_blocking(&mut rng);
        let g = 1 + rng.below(256);
        let s = 1 + rng.below(8);
        let p = 1 + rng.below(128);
        for d in [
            Decomposition::DataParallel,
            Decomposition::FixedSplit { s },
            Decomposition::StreamK { g },
            Decomposition::HybridOneTile { p },
            Decomposition::HybridTwoTile { p },
        ] {
            let plan = decomp::plan(shape, blk, d);
            plan.validate()
                .unwrap_or_else(|e| panic!("case {case} {d:?} {shape:?}: {e:#}"));
        }
    }
}

#[test]
fn prop_stream_k_imbalance_at_most_one() {
    let mut rng = Rng::new(0x51EF);
    for case in 0..CASES {
        let shape = random_shape(&mut rng);
        let blk = random_blocking(&mut rng);
        let g = 1 + rng.below(256);
        let plan = decomp::plan(shape, blk, Decomposition::StreamK { g });
        assert!(
            plan.iter_imbalance() <= 1,
            "case {case} {shape:?} g={g}: imbalance {}",
            plan.iter_imbalance()
        );
    }
}

#[test]
fn prop_stream_k_generalizes_dp_and_fixed_split() {
    let mut rng = Rng::new(0x51F0);
    for _ in 0..40 {
        let shape = random_shape(&mut rng);
        let blk = random_blocking(&mut rng);
        let tiles = blk.tiles(shape);
        let ipt = blk.iters_per_tile(shape);

        // g == tiles: identical CTA set to data-parallel.
        let sk = decomp::plan(shape, blk, Decomposition::StreamK { g: tiles });
        let dp = decomp::plan(shape, blk, Decomposition::DataParallel);
        assert_eq!(sk.ctas, dp.ctas);

        // g == s*tiles with s | ipt: same per-CTA iteration multiset as
        // fixed-split.
        let s = 2usize;
        if ipt % (s as u64) == 0 && tiles > 0 {
            let sk = decomp::plan(shape, blk, Decomposition::StreamK { g: s * tiles });
            let fs = decomp::plan(shape, blk, Decomposition::FixedSplit { s });
            let mut a: Vec<u64> = sk.ctas.iter().map(|c| c.iters()).collect();
            let mut b: Vec<u64> = fs.ctas.iter().map(|c| c.iters()).collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "{shape:?} blk={blk:?}");
        }
    }
}

#[test]
fn prop_host_numerics_all_decompositions() {
    let mut rng = Rng::new(0x51F1);
    for case in 0..12 {
        // Small shapes (host GEMM is O(mnk)).
        let shape = GemmShape::new(rng.range(1, 90), rng.range(1, 90), rng.range(1, 120));
        let blk = Blocking::new(32, 32, 16);
        let a = DenseMat::random(shape.m, shape.k, rng.next_u64());
        let b = DenseMat::random(shape.k, shape.n, rng.next_u64());
        let want = DenseMat::matmul_ref(&a, &b);
        for d in [
            Decomposition::DataParallel,
            Decomposition::FixedSplit { s: 1 + rng.below(4) },
            Decomposition::StreamK { g: 1 + rng.below(12) },
            Decomposition::HybridTwoTile { p: 1 + rng.below(8) },
        ] {
            let plan = decomp::plan(shape, blk, d);
            let got = gemm::execute_plan_host(&a, &b, &plan);
            let err = got.max_abs_diff(&want);
            assert!(err < 1e-9, "case {case} {d:?} {shape:?}: err {err}");
        }
    }
}

#[test]
fn prop_model_share_covers_total() {
    let mut rng = Rng::new(0x51F2);
    for _ in 0..CASES {
        let shape = random_shape(&mut rng);
        let blk = random_blocking(&mut rng);
        let g = 1 + rng.below(256);
        let total = blk.total_iters(shape);
        let ipc = model::iters_per_cta(shape, blk, g);
        assert!(ipc * g as u64 >= total);
        assert!(ipc.saturating_sub(1) * (g as u64) < total || g as u64 > total);
        let peers = model::fixup_peers(shape, blk, g);
        assert!(peers >= 1 && peers <= blk.iters_per_tile(shape).max(1));
    }
}

#[test]
fn prop_best_grid_is_argmin() {
    let mut rng = Rng::new(0x51F3);
    let gpu = GpuSpec::a100();
    for _ in 0..30 {
        let shape = random_shape(&mut rng);
        let blk = Blocking::paper_default(Precision::F16F32);
        let m = gpulb::sim::CostModel::calibrate(&gpu, (blk.bm, blk.bn, blk.bk), Precision::F16F32);
        let best = model::best_grid(shape, blk, gpu.sms, &m);
        let t_best = model::time_cta(shape, blk, best, &m);
        for g in 1..=gpu.sms.min(blk.total_iters(shape) as usize) {
            assert!(
                t_best <= model::time_cta(shape, blk, g, &m) + 1e-15,
                "{shape:?}: best_grid {best} not argmin (g={g} better)"
            );
        }
    }
}

#[test]
fn prop_streamk_never_slower_than_dp_in_sim() {
    // The headline property on the simulator: the shipped Stream-K policy
    // (two-tile hybrid / model-selected grid, as in §5.3.2) is never
    // materially slower than the same-blocking data-parallel schedule.
    let mut rng = Rng::new(0x51F4);
    let gpu = GpuSpec::a100();
    let prec = Precision::F16F32;
    let blk = Blocking::paper_default(prec);
    for case in 0..40 {
        let shape = GemmShape::new(
            rng.range(128, 8192),
            rng.range(128, 8192),
            rng.range(128, 8192),
        );
        let sk = gpulb::report::figures::streamk_time(shape, &gpu, prec);
        let dp = gpulb::baselines::vendor_gemm::member_time(shape, blk, 1, &gpu, prec);
        assert!(
            sk <= dp * 1.05,
            "case {case} {shape:?}: sk {sk} vs dp {dp}"
        );
    }
}

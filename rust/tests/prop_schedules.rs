//! Property tests over the Chapter-4 schedules (seeded randomized cases —
//! the offline environment has no proptest crate, so case generation uses
//! the repo's deterministic RNG; every failure reproduces from its printed
//! seed).
//!
//! Invariants:
//! * exact cover — every atom assigned exactly once, segments in-bounds;
//! * numerics — every schedule's execution equals the sequential reference;
//! * merge-path even-share bound;
//! * nonzero-split atom-share bound;
//! * schedule interchangeability (identical y for all schedules).

use gpulb::balance::{merge_path, OffsetsSource, ScheduleKind};
use gpulb::exec::spmv;
use gpulb::rng::Rng;
use gpulb::sparse::{gen, Csr};

const CASES: usize = 60;

fn random_offsets(rng: &mut Rng) -> Vec<usize> {
    let tiles = rng.range(0, 60);
    let mut offsets = Vec::with_capacity(tiles + 1);
    offsets.push(0usize);
    for _ in 0..tiles {
        // Mix of empty, tiny, and giant tiles.
        let len = match rng.below(10) {
            0..=2 => 0,
            3..=7 => rng.range(1, 12),
            8 => rng.range(12, 80),
            _ => rng.range(80, 1200),
        };
        offsets.push(offsets.last().unwrap() + len);
    }
    offsets
}

fn random_matrix(rng: &mut Rng) -> Csr {
    let seed = rng.next_u64();
    match rng.below(5) {
        0 => gen::uniform(rng.range(1, 200), rng.range(1, 200), rng.range(1, 9), seed),
        1 => gen::power_law(
            rng.range(2, 300),
            rng.range(2, 300),
            rng.range(1, 150),
            1.2 + rng.f64(),
            seed,
        ),
        2 => gen::banded(rng.range(2, 200), rng.range(1, 6), seed),
        3 => gen::block_diag(rng.range(2, 128), rng.range(1, 9), seed),
        _ => gen::tall_skinny(rng.range(1, 400), rng.f64(), seed),
    }
}

const ALL_SCHEDULES: [ScheduleKind; 7] = [
    ScheduleKind::ThreadMapped,
    ScheduleKind::GroupMapped(32),
    ScheduleKind::GroupMapped(128),
    ScheduleKind::MergePath,
    ScheduleKind::NonzeroSplit,
    ScheduleKind::Binning,
    ScheduleKind::Lrb,
];

#[test]
fn prop_exact_cover_on_random_offsets() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..CASES {
        let offsets = random_offsets(&mut rng);
        let src = OffsetsSource::new(&offsets);
        let workers = 1 + rng.below(300);
        for kind in ALL_SCHEDULES {
            let asg = kind.assign(&src, workers);
            asg.validate(&src)
                .unwrap_or_else(|e| panic!("case {case} {kind:?} workers={workers}: {e:#}"));
        }
    }
}

#[test]
fn prop_numerics_match_reference_on_random_matrices() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..CASES {
        let a = random_matrix(&mut rng);
        let workers = 1 + rng.below(200);
        let x: Vec<f64> = (0..a.cols).map(|i| ((i * 7 + case) as f64 * 0.13).sin()).collect();
        let want = a.spmv_ref(&x);
        for kind in ALL_SCHEDULES {
            let asg = kind.assign(&a, workers);
            let got = spmv::execute_host(&a, &x, &asg);
            let err = got
                .iter()
                .zip(&want)
                .map(|(g, w)| (g - w).abs())
                .fold(0.0, f64::max);
            assert!(
                err < 1e-9,
                "case {case} {kind:?} workers={workers}: err {err}"
            );
        }
    }
}

#[test]
fn prop_merge_path_even_share() {
    let mut rng = Rng::new(0xDEAD);
    for case in 0..CASES {
        let offsets = random_offsets(&mut rng);
        let src = OffsetsSource::new(&offsets);
        let workers = 1 + rng.below(128);
        let asg = merge_path::assign(&src, workers);
        let per = merge_path::work_per_worker(&src, workers);
        for (i, w) in asg.workers.iter().enumerate() {
            let work = w.atoms() + w.segments.len();
            assert!(
                work <= per + 1,
                "case {case} worker {i}: work {work} > share {per}+1"
            );
        }
    }
}

#[test]
fn prop_nonzero_split_share_bound() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..CASES {
        let offsets = random_offsets(&mut rng);
        let src = OffsetsSource::new(&offsets);
        let atoms = *offsets.last().unwrap();
        let workers = 1 + rng.below(128);
        let asg = ScheduleKind::NonzeroSplit.assign(&src, workers);
        let per = atoms.div_ceil(workers.max(1)).max(1);
        for w in &asg.workers {
            assert!(w.atoms() <= per, "case {case}: {} > {per}", w.atoms());
        }
    }
}

#[test]
fn prop_schedules_interchangeable() {
    // The paper's core claim: swapping the schedule never changes results.
    let mut rng = Rng::new(0xFACE);
    for _ in 0..20 {
        let a = random_matrix(&mut rng);
        let x: Vec<f64> = (0..a.cols).map(|i| (i as f64).cos()).collect();
        let baseline = spmv::execute_host(&a, &x, &ALL_SCHEDULES[0].assign(&a, 33));
        for kind in &ALL_SCHEDULES[1..] {
            let y = spmv::execute_host(&a, &x, &kind.assign(&a, 77));
            let err = baseline
                .iter()
                .zip(&y)
                .map(|(b, v)| (b - v).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-9, "{kind:?} diverged: {err}");
        }
    }
}

#[test]
fn prop_queue_policies_conserve_tasks() {
    use gpulb::balance::queue::{simulate, QueueParams, QueuePolicy};
    let mut rng = Rng::new(0xAB1E);
    for case in 0..30 {
        let n = 1 + rng.below(200);
        let tasks: Vec<usize> = (0..n).map(|_| rng.below(500)).collect();
        let workers = 1 + rng.below(16);
        for policy in [
            QueuePolicy::StaticList,
            QueuePolicy::Centralized,
            QueuePolicy::Stealing,
            QueuePolicy::Donation { capacity: 1 + rng.below(8) },
            QueuePolicy::ChunkedFetch { chunk: 1 + rng.below(16) },
        ] {
            let r = simulate(
                policy,
                workers,
                tasks.clone(),
                |_| Vec::new(),
                QueueParams::default(),
            );
            assert_eq!(r.processed, n, "case {case} {policy:?}");
            let u = r.utilization();
            assert!(
                (0.0..=1.0 + 1e-9).contains(&u),
                "case {case} {policy:?}: u={u}"
            );
        }
    }
}

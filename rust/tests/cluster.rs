//! Cluster-engine contracts: the bit-identity matrix (checksums equal
//! across device counts × threads-per-pool × migration settings, and
//! equal to a single `ServeEngine` run) and placement determinism.
//!
//! Run with `RUST_TEST_THREADS=1` in CI: the matrix spawns its own device
//! pools, so test-level parallelism only adds scheduling noise.

use gpulb::serve::{
    cluster_gate_mix, parse_devices, ClusterEngine, CostFeedback, ServeConfig, ServeEngine,
};

/// Auto policy (the default): static schedule choice is a pure function
/// of the problem, so placement cannot leak into the numerics.  The
/// split threshold sits below the smoke mix's two heavy problems, so
/// multi-device runs exercise the cross-device shard path.
fn cfg(threads: usize) -> ServeConfig {
    ServeConfig::builder()
        .threads(threads)
        .plan_workers(64)
        .feedback(CostFeedback::Proxy)
        .split_min_atoms(60_000)
        .build()
        .unwrap()
}

const SPECS: [&str; 3] = ["v100:1", "a100:1,v100:1", "a100:2,v100:2"];

#[test]
fn checksums_bit_identical_across_devices_threads_and_migration() {
    let mix = cluster_gate_mix(0);
    let reference = ServeEngine::new(cfg(1)).execute_batch(&mix).checksums;
    assert!(reference.iter().all(|c| c.is_finite()));

    for spec in SPECS {
        let devices = parse_devices(spec).unwrap();
        for threads in [1usize, 2, 4, 8] {
            for migration in [false, true] {
                let engine =
                    ClusterEngine::new(cfg(threads), devices.clone(), migration).unwrap();
                let report = engine.execute_batch(&mix);
                assert!(report.faults.is_clean(), "{spec} t{threads}");
                for (i, (got, want)) in report.checksums.iter().zip(&reference).enumerate() {
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "problem {i} diverged on {spec} threads={threads} \
                         migration={migration}"
                    );
                }
                if devices.len() > 1 {
                    assert!(
                        report.shard_problems > 0,
                        "{spec}: heavy problems should shard across devices"
                    );
                } else {
                    assert_eq!(report.shard_problems, 0);
                }
            }
        }
    }
}

#[test]
fn placement_is_deterministic_across_runs_and_engines() {
    let mix = cluster_gate_mix(0);
    for spec in SPECS {
        let devices = parse_devices(spec).unwrap();
        for migration in [false, true] {
            let a = ClusterEngine::new(cfg(2), devices.clone(), migration)
                .unwrap()
                .execute_batch(&mix);
            let b = ClusterEngine::new(cfg(4), devices.clone(), migration)
                .unwrap()
                .execute_batch(&mix);
            // Placement is decided by the virtual-time simulation before
            // any kernel runs: identical across runs, fresh engines, and
            // threads-per-pool.
            assert_eq!(a.placements, b.placements, "{spec} migration={migration}");
            assert_eq!(a.schedules, b.schedules);
            assert_eq!(a.device_problems, b.device_problems);
            assert_eq!(a.migrated, b.migrated);
            assert_eq!(a.makespan_est, b.makespan_est);
        }
    }
}

#[test]
fn device_list_parsing_pins_the_cli_surface() {
    let devices = parse_devices("a100:2,v100:1").unwrap();
    assert_eq!(devices.len(), 3);
    assert_eq!(devices[0].class, "a100");
    assert_eq!(devices[2].class, "v100");
    assert_eq!(devices[2].speed, 1.0);
    for bad in ["", "a100", "a100:0", "k80:1", "a100:2,"] {
        assert!(parse_devices(bad).is_err(), "{bad:?} parsed");
    }
}

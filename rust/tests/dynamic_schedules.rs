//! Dynamic schedules, end to end: the acceptance matrix for the §3.3.5
//! promotion from simulation to the serve engine.
//!
//! * For every kernel family (spmv, spmm, spgemm, gemm, frontier), the
//!   checksum under `WorkStealing` and `ChunkedFetch` at 1/2/4/8 threads
//!   is **bit-identical** to the planned `ThreadMapped` checksum for the
//!   same problem — the segment-keyed canonical reduction at work.
//! * The `balance/queue` virtual-time simulation and the real executors
//!   agree on the same workload: same tiles processed, same total atoms,
//!   and the simulated chunked-fetch pop count equals the real cursor
//!   claim count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use gpulb::balance::dynamic::{self, DynamicDescriptor};
use gpulb::balance::queue::{self, QueuePolicy, QueueParams};
use gpulb::balance::{OffsetsSource, ScheduleKind};
use gpulb::serve::{Problem, SchedulePolicy, ServeConfig, ServeEngine};
use gpulb::sparse::gen;
use gpulb::streamk::{Blocking, GemmShape};

const DYNAMIC_KINDS: [ScheduleKind; 2] = [
    ScheduleKind::WorkStealing { chunk: 8 },
    ScheduleKind::ChunkedFetch { chunk: 8 },
];

/// One problem per kernel family, sized so every family has real skew.
fn five_kernel_mix() -> Vec<Problem> {
    let a = Arc::new(gen::power_law(192, 192, 96, 1.6, 71));
    let b = Arc::new(gen::uniform(192, 128, 4, 72));
    let graph = Arc::new(gen::rmat(7, 4, 73));
    let frontier: Vec<u32> = (0..graph.rows as u32).step_by(2).collect();
    vec![
        Problem::spmv(a.clone()),
        Problem::spmm(a.clone(), 3),
        Problem::spgemm(a, b),
        Problem::gemm(GemmShape::new(64, 48, 40), Blocking::new(16, 16, 8), 9),
        Problem::frontier(graph, frontier),
    ]
}

fn engine(threads: usize, kind: ScheduleKind) -> ServeEngine {
    ServeEngine::new(
        ServeConfig::builder()
            .threads(threads)
            .plan_workers(64)
            .schedule(SchedulePolicy::Fixed(kind))
            // Force the real claimed path for every problem size (dynamic
            // problems below this threshold run whole in the batch pool).
            .split_min_atoms(1)
            .build()
            .unwrap(),
    )
}

#[test]
fn dynamic_checksums_bit_identical_to_thread_mapped_across_threads() {
    let mix = five_kernel_mix();
    let reference = engine(1, ScheduleKind::ThreadMapped)
        .execute_batch(&mix)
        .checksums;
    for kind in DYNAMIC_KINDS {
        for threads in [1usize, 2, 4, 8] {
            let report = engine(threads, kind).execute_batch(&mix);
            for (i, (got, want)) in report.checksums.iter().zip(&reference).enumerate() {
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{} under {kind:?} x{threads} diverged from planned \
                     thread-mapped: {got} vs {want}",
                    mix[i].kind_name()
                );
            }
            if threads > 1 {
                assert_eq!(
                    report.dynamic_problems,
                    mix.len(),
                    "{kind:?} x{threads}: every problem must take the claimed path"
                );
            }
        }
    }
}

#[test]
fn dynamic_checksums_are_repeatable_across_runs() {
    // Claim order is nondeterministic; results must not be.  Re-running
    // the same dynamic batch at high thread counts lands on the same bits
    // every time.
    let mix = five_kernel_mix();
    for kind in DYNAMIC_KINDS {
        let first = engine(8, kind).execute_batch(&mix).checksums;
        for _ in 0..3 {
            let again = engine(8, kind).execute_batch(&mix).checksums;
            let same = first
                .iter()
                .zip(&again)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{kind:?}: nondeterministic checksums");
        }
    }
}

#[test]
fn queue_simulation_cross_validates_real_dynamic_execution() {
    // The same workload, twice: once through the §3.3.5 virtual-time
    // simulation (`balance/queue`), once through the real promoted
    // executors — tiles, atoms and (for chunked fetch) claim counts must
    // line up, and the real execution's numerics must match the planned
    // reference.
    let a = Arc::new(gen::hotrow(512, 512, 16, 64, 4));
    let lens: Vec<usize> = (0..a.rows).map(|r| a.row_nnz(r)).collect();
    let atoms: usize = lens.iter().sum();
    assert_eq!(atoms, 16 * 64 + 496 * 4);
    let threads = 4;
    let chunk = 8usize;

    // Virtual time: one task per tile, chunked fetch drains `chunk` tasks
    // per synchronized pop.
    let stealing_sim = queue::simulate(
        QueuePolicy::Stealing,
        threads,
        lens.clone(),
        |_| Vec::new(),
        QueueParams::default(),
    );
    assert_eq!(stealing_sim.processed, a.rows, "sim must process every tile");
    let fetch_sim = queue::simulate(
        QueuePolicy::ChunkedFetch { chunk },
        threads,
        lens.clone(),
        |_| Vec::new(),
        QueueParams::default(),
    );
    assert_eq!(fetch_sim.processed, a.rows);

    // Real time: the same tile set claimed in `chunk`-tile runs.
    let offsets = a.offsets.clone();
    let src = OffsetsSource::new(&offsets);
    for kind in [
        ScheduleKind::WorkStealing {
            chunk: chunk as u32,
        },
        ScheduleKind::ChunkedFetch {
            chunk: chunk as u32,
        },
    ] {
        let dd = DynamicDescriptor::new(kind, &src, 64).unwrap();
        let claimed_atoms = AtomicUsize::new(0);
        let claimed_tiles = AtomicUsize::new(0);
        let (chunks_seen, stats) = dynamic::execute_claimed(&dd, threads, |j| {
            let t0 = j * chunk;
            let t1 = (t0 + chunk).min(a.rows);
            claimed_tiles.fetch_add(t1 - t0, Ordering::Relaxed);
            claimed_atoms.fetch_add(offsets[t1] - offsets[t0], Ordering::Relaxed);
            j
        });
        assert_eq!(chunks_seen.len(), dd.chunks(), "{kind:?}");
        assert_eq!(stats.claims, dd.chunks() as u64);
        // Exactly the simulation's coverage: every tile once, every atom
        // once.
        assert_eq!(claimed_tiles.into_inner(), a.rows, "{kind:?}");
        assert_eq!(claimed_atoms.into_inner(), atoms, "{kind:?}");
        if let ScheduleKind::ChunkedFetch { .. } = kind {
            // One amortized synchronized claim per chunk — the very count
            // the simulation models as `pops`.
            assert_eq!(stats.fetches as usize, fetch_sim.pops, "{kind:?}");
        }
    }

    // And the numerics: real dynamic execution of this matrix equals the
    // planned thread-mapped checksum, bit for bit.
    let mix = vec![Problem::spmv(a)];
    let want = engine(1, ScheduleKind::ThreadMapped)
        .execute_batch(&mix)
        .checksums[0];
    for kind in DYNAMIC_KINDS {
        let got = engine(threads, kind).execute_batch(&mix).checksums[0];
        assert_eq!(got.to_bits(), want.to_bits(), "{kind:?}");
    }
}

#[test]
fn adaptive_with_restricted_dynamic_candidates_keeps_bitwise_determinism() {
    // An adaptive engine exploring a CLI-style restricted candidate set
    // that mixes a planned schedule with the dynamic kinds: traces replay
    // per seed and checksums stay bit-identical across thread counts even
    // though dynamic executions claim at runtime.
    let mix = five_kernel_mix();
    let candidates = vec![ScheduleKind::MergePath, DYNAMIC_KINDS[0], DYNAMIC_KINDS[1]];
    let cfg = |threads: usize| {
        ServeConfig::builder()
            .threads(threads)
            .plan_workers(64)
            .schedule(SchedulePolicy::Adaptive {
                epsilon: 0.05,
                min_samples: 1,
                seed: 99,
            })
            .feedback(gpulb::serve::CostFeedback::Proxy)
            .candidates(candidates.clone())
            .split_min_atoms(1)
            .build()
            .unwrap()
    };
    let runs: Vec<(Vec<Vec<ScheduleKind>>, Vec<Vec<u64>>)> = [1usize, 4]
        .iter()
        .map(|&threads| {
            let e = ServeEngine::new(cfg(threads));
            let mut traces = Vec::new();
            let mut sums = Vec::new();
            for _ in 0..8 {
                let report = e.execute_batch(&mix);
                assert_eq!(report.candidates, candidates, "candidate set surfaced");
                assert!(
                    report.schedules.iter().all(|k| candidates.contains(k)),
                    "selection outside the restricted set"
                );
                sums.push(report.checksums.iter().map(|c| c.to_bits()).collect());
                traces.push(report.schedules);
            }
            (traces, sums)
        })
        .collect();
    assert_eq!(runs[0].0, runs[1].0, "trace must not depend on threads");
    assert_eq!(runs[0].1, runs[1].1, "checksums must not depend on threads");
    // The dynamic kinds actually got explored, not just listed.
    assert!(runs[0].0.iter().flatten().any(|k| k.is_dynamic()));
}

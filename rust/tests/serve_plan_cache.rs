//! Property tests for the serve plan cache (seeded randomized cases, like
//! `prop_schedules.rs`): a cached plan entry must reproduce a freshly
//! computed assignment bit for bit for **every** schedule, work source,
//! and worker count — the invariant that makes plan caching a pure
//! optimization.  Streaming-capable schedules cache O(1) descriptors
//! (materialized on demand through the stream); Binning/LRB cache the
//! materialized assignment.

use gpulb::balance::{stream, OffsetsSource, ScheduleKind};
use gpulb::rng::Rng;
use gpulb::serve::{fingerprint, PlanCache, PlanEntry, PlanKey};
use gpulb::sparse::{gen, Csr};

const SCHEDULES: [ScheduleKind; 7] = [
    ScheduleKind::ThreadMapped,
    ScheduleKind::GroupMapped(32),
    ScheduleKind::GroupMapped(128),
    ScheduleKind::MergePath,
    ScheduleKind::NonzeroSplit,
    ScheduleKind::Binning,
    ScheduleKind::Lrb,
];

fn random_matrix(rng: &mut Rng) -> Csr {
    let seed = rng.next_u64();
    match rng.below(4) {
        0 => gen::uniform(rng.range(1, 150), rng.range(1, 150), rng.range(1, 8), seed),
        1 => {
            let n = rng.range(2, 200);
            gen::power_law(n, n, (n / 2).max(1), 1.2 + rng.f64(), seed)
        }
        2 => gen::banded(rng.range(1, 200), rng.range(1, 6), seed),
        _ => gen::rmat(rng.range(4, 8) as u32, rng.range(1, 6), seed),
    }
}

/// Materialized view of an entry, whatever its representation.
fn materialized(entry: &PlanEntry, a: &Csr) -> gpulb::balance::Assignment {
    match entry {
        PlanEntry::Descriptor(d) => stream::materialize(*d, a),
        PlanEntry::Dynamic(dd) => dd.assign_snapshot(a),
        PlanEntry::Materialized(asg) => (**asg).clone(),
    }
}

#[test]
fn prop_cached_plan_bit_identical_to_fresh() {
    let mut rng = Rng::new(0x5EED_CAC8);
    let cache = PlanCache::new(4096);
    for case in 0..10 {
        let a = random_matrix(&mut rng);
        let fp = fingerprint(0, &a);
        for kind in SCHEDULES {
            for workers in [1usize, 7, 64, 256] {
                let key = PlanKey {
                    fingerprint: fp,
                    schedule: kind,
                    workers,
                };
                let cached = cache.plan(key, &a);
                let fresh = kind.assign(&a, workers);
                assert_eq!(
                    materialized(&cached, &a),
                    fresh,
                    "case {case}: {kind:?} x{workers} cached plan diverged"
                );
                fresh.validate(&a).unwrap();
                // Streaming-capable schedules must cache descriptors only.
                assert_eq!(
                    cached.is_descriptor(),
                    !matches!(kind, ScheduleKind::Binning | ScheduleKind::Lrb),
                    "case {case}: {kind:?} wrong entry representation"
                );
                // Refetching must hit and return an equivalent entry.
                let again = cache.get_or_compute(key, || panic!("unexpected recompute"));
                assert_eq!(again.workers(), cached.workers());
            }
        }
    }
    let stats = cache.stats();
    // Every key is refetched once after insertion (distinct sources can
    // legitimately share offsets, hence ">=" rather than "==").
    assert!(stats.hits >= stats.misses, "stats: {stats:?}");
    assert_eq!(stats.evictions, 0);
}

#[test]
fn prop_fingerprint_keys_offsets_exactly() {
    // Same offsets => same fingerprint (plans shareable); any tweak to one
    // tile's atom count => different fingerprint.
    let mut rng = Rng::new(0xF16E_4011);
    for _ in 0..20 {
        let tiles = rng.range(1, 40);
        let mut lens: Vec<usize> = (0..tiles).map(|_| rng.below(9)).collect();
        let offsets = gpulb::balance::prefix::exclusive(&lens);
        let fp = fingerprint(3, &OffsetsSource::new(&offsets));
        assert_eq!(fp, fingerprint(3, &OffsetsSource::new(&offsets)));

        let t = rng.below(tiles);
        lens[t] += 1;
        let tweaked = gpulb::balance::prefix::exclusive(&lens);
        assert_ne!(fp, fingerprint(3, &OffsetsSource::new(&tweaked)));
    }
}

#[test]
fn workers_and_schedule_are_part_of_the_key() {
    let a = gen::power_law(120, 120, 60, 1.5, 9);
    let cache = PlanCache::new(64);
    let fp = fingerprint(0, &a);
    let plan_64 = cache.plan(
        PlanKey {
            fingerprint: fp,
            schedule: ScheduleKind::MergePath,
            workers: 64,
        },
        &a,
    );
    let plan_128 = cache.plan(
        PlanKey {
            fingerprint: fp,
            schedule: ScheduleKind::MergePath,
            workers: 128,
        },
        &a,
    );
    assert_eq!(cache.stats().misses, 2, "worker count must key separately");
    assert_ne!(plan_64.workers(), plan_128.workers());
}

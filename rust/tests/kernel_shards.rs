//! Shard-reduction edge cases, property-tested across every kernel:
//! zero-atom workers (plans with far more workers than atoms), empty
//! shards (worker ranges holding no segments), and 1-shard degenerate
//! splits, at the trait level and through the engine at 1/2/4/8 threads.
//! Checksums must be bit-identical to sequential execution everywhere —
//! the contract `WorkKernel::reduce` documents.

use std::sync::Arc;

use gpulb::balance::{OffsetsSource, ScheduleKind};
use gpulb::exec::kernel::{
    DynKernel, FrontierKernel, GemmKernel, SpgemmKernel, SpmmKernel, SpmvKernel,
};
use gpulb::serve::{CostFeedback, Problem, SchedulePolicy, ServeConfig, ServeEngine};
use gpulb::sparse::Csr;
use gpulb::streamk::{Blocking, GemmShape};

const STREAMING: [ScheduleKind; 4] = [
    ScheduleKind::ThreadMapped,
    ScheduleKind::GroupMapped(32),
    ScheduleKind::MergePath,
    ScheduleKind::NonzeroSplit,
];

/// A matrix with explicit empty rows (repeated offsets), a hub row, and
/// degree-1 tails: every shard-boundary shape in one source.
fn gappy_matrix() -> Arc<Csr> {
    let lens = [0usize, 5, 0, 0, 17, 1, 0, 3, 0, 0, 9, 2, 0, 1, 0, 4];
    let mut offsets = vec![0usize];
    for l in lens {
        offsets.push(offsets.last().unwrap() + l);
    }
    let nnz = *offsets.last().unwrap();
    let cols = 8usize;
    let indices: Vec<u32> = (0..nnz).map(|k| (k * 3 % cols) as u32).collect();
    let values: Vec<f64> = (0..nnz).map(|k| (k as f64 * 0.7).sin() + 0.1).collect();
    let csr = Csr::from_parts(lens.len(), cols, offsets, indices, values);
    Arc::new(csr.unwrap())
}

/// A B-operand with empty rows too (rows must match `gappy_matrix` cols).
fn gappy_rhs() -> Arc<Csr> {
    let lens = [2usize, 0, 3, 0, 0, 1, 4, 0];
    let mut offsets = vec![0usize];
    for l in lens {
        offsets.push(offsets.last().unwrap() + l);
    }
    let nnz = *offsets.last().unwrap();
    let cols = 6usize;
    let indices: Vec<u32> = (0..nnz).map(|k| (k * 5 % cols) as u32).collect();
    let values: Vec<f64> = (0..nnz).map(|k| (k as f64 * 0.3).cos() + 0.2).collect();
    let csr = Csr::from_parts(lens.len(), cols, offsets, indices, values);
    Arc::new(csr.unwrap())
}

fn edge_kernels() -> Vec<(&'static str, Arc<dyn DynKernel>)> {
    let a = gappy_matrix();
    let frontier: Vec<u32> = (0..a.rows as u32).collect();
    vec![
        ("spmv", Arc::new(SpmvKernel::new(a.clone()))),
        ("spmm", Arc::new(SpmmKernel::new(a.clone(), 3))),
        ("spgemm", Arc::new(SpgemmKernel::new(a.clone(), gappy_rhs()))),
        (
            "gemm",
            Arc::new(GemmKernel::new(
                GemmShape::new(40, 33, 20),
                Blocking::new(16, 16, 8),
                11,
            )),
        ),
        ("frontier", Arc::new(FrontierKernel::new(a, frontier))),
    ]
}

#[test]
fn shard_reductions_bit_identical_across_all_kernels_and_splits() {
    for (name, k) in edge_kernels() {
        let offsets = k.offsets().to_vec();
        let src = OffsetsSource::new(&offsets);
        // workers 64 >> atoms: most workers own zero atoms.
        for workers in [1usize, 4, 64] {
            for kind in STREAMING {
                let Some(desc) = kind.descriptor(&src, workers) else {
                    continue;
                };
                if desc.workers() == 0 {
                    continue;
                }
                let want = k.execute_stream(&desc);
                for shards in [1usize, 2, 4, 8] {
                    let per = desc.workers().div_ceil(shards).max(1);
                    let mut parts = Vec::new();
                    let mut w0 = 0;
                    while w0 < desc.workers() {
                        let w1 = (w0 + per).min(desc.workers());
                        parts.push(k.shard_dyn(&desc, w0, w1));
                        w0 = w1;
                    }
                    // An explicitly empty shard range must be a no-op.
                    parts.push(k.shard_dyn(&desc, desc.workers(), desc.workers()));
                    let got = k.reduce_dyn(parts);
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "{name} {kind:?} workers={workers} shards={shards} diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn engine_split_path_bit_identical_for_every_kernel_at_all_thread_counts() {
    let a = gappy_matrix();
    let mix = vec![
        Problem::spmv(a.clone()),
        Problem::spmm(a.clone(), 3),
        Problem::spgemm(a.clone(), gappy_rhs()),
        Problem::gemm(GemmShape::new(40, 33, 20), Blocking::new(16, 16, 8), 11),
        Problem::frontier(a.clone(), (0..a.rows as u32).collect()),
    ];
    for kind in [ScheduleKind::MergePath, ScheduleKind::NonzeroSplit] {
        let cfg = |threads: usize, split_min_atoms: usize| {
            ServeConfig::builder()
                .threads(threads)
                .plan_workers(64)
                .schedule(SchedulePolicy::Fixed(kind))
                .split_min_atoms(split_min_atoms)
                .build()
                .unwrap()
        };
        // Reference: whole-problem sequential execution.
        let whole = ServeEngine::new(cfg(1, usize::MAX)).execute_batch(&mix);
        for threads in [1usize, 2, 4, 8] {
            // split_min_atoms = 1 forces the split/shard path for every
            // problem at threads >= 2; the threads = 1 point is the
            // whole-problem control (the engine never splits on one
            // thread).  The 1-shard degenerate reduce itself is covered
            // at the trait level by
            // shard_reductions_bit_identical_across_all_kernels_and_splits.
            let split = ServeEngine::new(cfg(threads, 1)).execute_batch(&mix);
            assert_eq!(
                split.checksums, whole.checksums,
                "{kind:?} at {threads} threads changed numerics"
            );
        }
    }
}

#[test]
fn spgemm_and_spmm_serve_through_cache_tuner_and_split() {
    use gpulb::sparse::gen;
    let a = Arc::new(gen::power_law(600, 600, 300, 1.6, 71));
    let b = Arc::new(gen::uniform(600, 600, 5, 72));
    let mix = vec![Problem::spgemm(a.clone(), b), Problem::spmm(a, 6)];

    // Reference: fixed merge-path, whole problems, one thread.
    let fixed = |threads: usize, split_min_atoms: usize| {
        ServeConfig::builder()
            .threads(threads)
            .plan_workers(64)
            .schedule(SchedulePolicy::Fixed(ScheduleKind::MergePath))
            .feedback(CostFeedback::Proxy)
            .split_min_atoms(split_min_atoms)
            .build()
            .unwrap()
    };
    let reference = ServeEngine::new(fixed(1, usize::MAX)).execute_batch(&mix);

    for threads in [1usize, 2, 4, 8] {
        // Split path: bit-identical through the two-phase fixup.
        let split = ServeEngine::new(fixed(threads, 1)).execute_batch(&mix);
        assert_eq!(
            split.checksums, reference.checksums,
            "split path at {threads} threads changed numerics"
        );

        // Adaptive tuner: deterministic proxy feedback replays the same
        // schedule trace at every thread count, so checksums match their
        // own 1-thread twin batch for batch.
        let adaptive = |threads: usize| {
            ServeConfig::builder()
                .threads(threads)
                .plan_workers(64)
                .schedule(SchedulePolicy::Adaptive {
                    epsilon: 0.05,
                    min_samples: 1,
                    seed: 0xC0FFEE,
                })
                .feedback(CostFeedback::Proxy)
                .split_min_atoms(1)
                .build()
                .unwrap()
        };
        let engine = ServeEngine::new(adaptive(threads));
        let twin = ServeEngine::new(adaptive(1));
        for round in 0..6 {
            let r = engine.execute_batch(&mix);
            let t = twin.execute_batch(&mix);
            assert_eq!(r.schedules, t.schedules, "trace diverged in round {round}");
            assert_eq!(
                r.checksums, t.checksums,
                "adaptive at {threads} threads diverged in round {round}"
            );
        }
    }

    // Plan-cache flow: a fresh engine plans once, then reuses.
    let engine = ServeEngine::new(fixed(4, usize::MAX));
    let first = engine.execute_batch(&mix);
    assert_eq!(first.cache.misses, mix.len() as u64);
    let second = engine.execute_batch(&mix);
    assert_eq!(second.cache.misses, first.cache.misses);
    assert!(second.cache.hits >= mix.len() as u64);
    assert_eq!(first.checksums, second.checksums);
}

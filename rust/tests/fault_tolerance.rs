//! Fault-tolerance acceptance: injected panics, stalls, and poisoned
//! checksums are isolated per problem, recovered through the planned
//! fallback retry bit-identically, counted deterministically at any
//! thread count, and surfaced as typed errors when the retry ladder is
//! exhausted — while overloaded ingest queues shed deterministically and
//! graceful drains leave no ticket unresolved.  Every engine run is
//! wrapped in a watchdog so a hang fails the test instead of the suite.

use std::sync::{mpsc, Arc};
use std::time::Duration;

use gpulb::balance::adaptive::PerfKey;
use gpulb::exec::chaos::{ChaosKernel, FaultPlan, DEFAULT_STALL_VIRT_SECS};
use gpulb::prelude::*;
use gpulb::serve::ingest::{IngestServer, Ticket};
use gpulb::sparse::gen;

/// Run `f` on a watchdog thread: a fault that hangs the engine fails the
/// test after the timeout instead of wedging the whole suite.
fn with_timeout<T: Send + 'static>(name: &str, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(120)) {
        Ok(value) => {
            let _ = handle.join();
            value
        }
        Err(_) => panic!("{name}: timed out — a fault hung the engine"),
    }
}

/// A small mixed-shape SpMV set; big enough (with `split_min_atoms(1)`)
/// to exercise the split and dynamic claimed paths.
fn chaos_mix() -> Vec<Problem> {
    vec![
        Problem::spmv(Arc::new(gen::uniform(64, 64, 4, 7))),
        Problem::spmv(Arc::new(gen::power_law(80, 80, 40, 1.5, 2))),
        Problem::spmv(Arc::new(gen::banded(96, 3, 5))),
    ]
}

/// Wrap problem `target` of the mix with `fault`; the rest stay clean.
fn wrap_one(mix: &[Problem], target: usize, fault: FaultKind) -> Vec<Problem> {
    mix.iter()
        .enumerate()
        .map(|(i, p)| {
            let fault = (i == target).then_some(fault);
            Problem::from_kernel(ChaosKernel::wrap(p.kernel().clone(), fault))
        })
        .collect()
}

fn engine(kind: ScheduleKind, threads: usize) -> Engine {
    Engine::new(
        ServeConfig::builder()
            .threads(threads)
            .schedule(SchedulePolicy::Fixed(kind))
            .split_min_atoms(1)
            .build()
            .unwrap(),
    )
}

/// The bit-identity schedules: every dynamic kind reduces identically to
/// planned `ThreadMapped` (the kernel contract), so the fallback retry
/// reproduces the fault-free checksum exactly.  `MergePath` is excluded
/// on purpose — its fixup is only ~1e-9-equal to the fallback.
const MATRIX_SCHEDULES: [ScheduleKind; 3] = [
    ScheduleKind::ThreadMapped,
    ScheduleKind::WorkStealing { chunk: 8 },
    ScheduleKind::ChunkedFetch { chunk: 8 },
];

#[test]
fn injected_faults_recover_bit_identically_across_the_matrix() {
    with_timeout("chaos matrix", || {
        let mix = chaos_mix();
        let reference = engine(ScheduleKind::ThreadMapped, 1)
            .execute_batch(&mix)
            .checksums;
        let faults = [
            FaultKind::Panic { worker: 3 },
            FaultKind::Stall {
                virt_secs: DEFAULT_STALL_VIRT_SECS,
            },
            FaultKind::Poison,
        ];
        for kind in MATRIX_SCHEDULES {
            for threads in [1usize, 2, 4, 8] {
                for fault in faults {
                    let chaotic = wrap_one(&mix, 1, fault);
                    let report = engine(kind, threads).execute_batch(&chaotic);
                    let tag = format!("{kind:?} x{threads} {fault:?}");
                    // One fault, classified by kind, recovered in one
                    // fallback retry — deterministically, at any threads.
                    let f = report.faults;
                    assert_eq!(f.faulted(), 1, "{tag}: {f:?}");
                    match fault {
                        FaultKind::Panic { .. } => assert_eq!(f.panics, 1, "{tag}"),
                        FaultKind::Stall { .. } => assert_eq!(f.timeouts, 1, "{tag}"),
                        FaultKind::Poison => assert_eq!(f.poisons, 1, "{tag}"),
                    }
                    assert_eq!((f.retries, f.recovered, f.failed), (1, 1, 0), "{tag}");
                    assert!(report.errors.iter().all(Option::is_none), "{tag}");
                    // The recovery contract: bit-identical to fault-free.
                    for (i, (got, want)) in
                        report.checksums.iter().zip(&reference).enumerate()
                    {
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "{tag}: problem {i} diverged after recovery"
                        );
                    }
                }
            }
        }
    })
}

#[test]
fn fault_plan_counters_are_deterministic_across_threads_and_reruns() {
    with_timeout("fault plan determinism", || {
        // A wider mix so a 0.5 rate faults several problems.
        let mix: Vec<Problem> = (0..4).flat_map(|_| chaos_mix()).collect();
        let plan = FaultPlan::new(0xC4A0_5EED, 0.5);
        let expected_faults = (0..mix.len())
            .filter(|&i| plan.fault_for(i).is_some())
            .count() as u64;
        assert!(expected_faults > 0, "seed draws no faults — pick another");
        let reference = engine(ScheduleKind::WorkStealing { chunk: 8 }, 1)
            .execute_batch(&mix)
            .checksums;
        let mut seen: Option<FaultBatchStats> = None;
        for threads in [1usize, 2, 4, 8, 2] {
            // Fresh wrappers per run: the one-shot latch must re-fire
            // identically on a rerun (last iteration repeats threads=2).
            let chaotic: Vec<Problem> = mix
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    Problem::from_kernel(ChaosKernel::wrap(p.kernel().clone(), plan.fault_for(i)))
                })
                .collect();
            let report = engine(ScheduleKind::WorkStealing { chunk: 8 }, threads)
                .execute_batch(&chaotic);
            assert_eq!(report.faults.faulted(), expected_faults, "x{threads}");
            assert_eq!(report.faults.recovered, expected_faults, "x{threads}");
            assert_eq!(report.faults.failed, 0, "x{threads}");
            match &seen {
                None => seen = Some(report.faults),
                Some(first) => assert_eq!(
                    *first, report.faults,
                    "counters diverged at {threads} threads"
                ),
            }
            for (i, (got, want)) in report.checksums.iter().zip(&reference).enumerate() {
                assert_eq!(got.to_bits(), want.to_bits(), "problem {i} x{threads}");
            }
        }
    })
}

#[test]
fn exhausted_retry_ladder_reports_typed_errors_not_poison() {
    with_timeout("retry exhaustion", || {
        let mix = chaos_mix();
        // Nested wrappers fail twice: the first execution and the single
        // fallback retry — the ladder exhausts.
        let chaotic: Vec<Problem> = mix
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let kernel = if i == 0 {
                    ChaosKernel::wrap(
                        ChaosKernel::wrap(p.kernel().clone(), Some(FaultKind::Poison)),
                        Some(FaultKind::Poison),
                    )
                } else {
                    p.kernel().clone()
                };
                Problem::from_kernel(kernel)
            })
            .collect();
        let report = engine(ScheduleKind::ThreadMapped, 4).execute_batch(&chaotic);
        assert_eq!(
            report.errors[0],
            Some(ServeError::Poisoned { retries: 1 }),
            "faults: {:?}",
            report.faults
        );
        assert!(report.checksums[0].is_nan());
        let f = report.faults;
        assert_eq!((f.poisons, f.retries, f.recovered, f.failed), (1, 1, 0, 1));
        // The healthy problems are untouched.
        assert!(report.errors[1..].iter().all(Option::is_none));
        assert!(report.checksums[1..].iter().all(|c| c.is_finite()));
        // The typed error formats with its retry count.
        let shown = format!("{}", report.errors[0].unwrap());
        assert!(shown.contains('1'), "{shown}");
    })
}

#[test]
fn failed_problems_feed_no_tuner_samples() {
    with_timeout("tuner hygiene", || {
        let mix = chaos_mix();
        // Problem 0 always times out (nested stall wrappers beat the
        // single retry); the others run clean.
        let chaotic: Vec<Problem> = mix
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let stall = FaultKind::Stall {
                    virt_secs: DEFAULT_STALL_VIRT_SECS,
                };
                let kernel = if i == 0 {
                    ChaosKernel::wrap(
                        ChaosKernel::wrap(p.kernel().clone(), Some(stall)),
                        Some(stall),
                    )
                } else {
                    p.kernel().clone()
                };
                Problem::from_kernel(kernel)
            })
            .collect();
        let cfg = ServeConfig::builder()
            .threads(2)
            .schedule(SchedulePolicy::Adaptive {
                epsilon: 0.0,
                min_samples: 1,
                seed: 11,
            })
            .feedback(CostFeedback::Proxy)
            .build()
            .unwrap();
        let workers = cfg.plan_workers;
        let engine = Engine::new(cfg);
        let report = engine.execute_batch(&chaotic);
        assert_eq!(report.faults.failed, 1);
        assert_eq!(report.errors[0], Some(ServeError::TimedOut { retries: 1 }));
        let tuner = engine.tuner().expect("adaptive policy builds a tuner");
        // The failed problem recorded nothing — a synthetic timeout can
        // never shift the learned best for its fingerprint.
        let fp = chaotic[0].fingerprint();
        for &kind in tuner.candidates() {
            assert_eq!(
                tuner.history().samples(&PerfKey {
                    fingerprint: fp,
                    schedule: kind,
                    workers,
                }),
                0,
                "{kind:?} got a sample from a failed problem"
            );
        }
        assert_eq!(tuner.best(fp, workers), None);
        // The clean problems did feed back.
        let clean_fp = chaotic[1].fingerprint();
        let clean_samples: u32 = tuner
            .candidates()
            .iter()
            .map(|&kind| {
                tuner.history().samples(&PerfKey {
                    fingerprint: clean_fp,
                    schedule: kind,
                    workers,
                })
            })
            .sum();
        assert!(clean_samples > 0, "clean problems must keep feeding back");
    })
}

#[test]
fn overloaded_ingest_sheds_deterministically_and_accounts_every_submission() {
    with_timeout("shed accounting", || {
        let mix = chaos_mix();
        let direct = engine(ScheduleKind::ThreadMapped, 2)
            .execute_batch(&mix)
            .checksums;
        let server = IngestServer::start(
            Arc::new(engine(ScheduleKind::ThreadMapped, 2)),
            IngestConfig::builder()
                .max_batch(4)
                .max_wait(Duration::from_millis(2))
                .queue_capacity(2)
                .build()
                .unwrap(),
        );
        let handle = server.handle();
        let submitted = 30usize;
        let tickets: Vec<_> = (0..submitted)
            .map(|i| {
                let p = mix[i % mix.len()].clone();
                (i, handle.submit(p, IngestClass::Bulk).unwrap())
            })
            .collect();
        drop(handle);
        let mut ok = 0usize;
        let mut shed = 0usize;
        for (i, ticket) in tickets {
            match ticket.wait() {
                Ok(completion) => {
                    ok += 1;
                    assert_eq!(
                        completion.checksum.to_bits(),
                        direct[i % mix.len()].to_bits(),
                        "request {i} diverged through the front-end"
                    );
                }
                Err(ServeError::Shed { class }) => {
                    shed += 1;
                    assert_eq!(class, IngestClass::Bulk);
                }
                Err(other) => panic!("request {i}: unexpected {other}"),
            }
        }
        let report = server.finish().unwrap();
        // Every submission is accounted exactly once: served or shed.
        assert_eq!(ok + shed, submitted);
        assert_eq!(report.requests, ok);
        assert_eq!(report.shed_total(), shed as u64);
        // Bulk's shed column carries all of it (Bulk-only traffic).
        assert_eq!(report.shed, [0, 0, shed as u64]);
        assert!(report.faults.is_clean());
    })
}

#[test]
fn drain_flushes_the_queue_and_resolves_every_ticket() {
    with_timeout("graceful drain", || {
        let mix = chaos_mix();
        let server = IngestServer::start(
            Arc::new(engine(ScheduleKind::ThreadMapped, 2)),
            IngestConfig::builder()
                .max_batch(4)
                .max_wait(Duration::from_millis(2))
                .build()
                .unwrap(),
        );
        let handle = server.handle();
        let tickets: Vec<_> = (0..12)
            .map(|i| handle.submit(mix[i % mix.len()].clone(), IngestClass::Standard).unwrap())
            .collect();
        // Drain with the handle still alive: admission closes, queued
        // work flushes, and every outstanding ticket resolves.
        let report = server.drain().unwrap();
        assert_eq!(report.requests, 12);
        for (i, ticket) in tickets.into_iter().enumerate() {
            let completion = ticket.wait();
            assert!(completion.is_ok(), "ticket {i}: {completion:?}");
        }
        // Submissions after the drain resolve Closed instead of hanging.
        let late = handle
            .submit(mix[0].clone(), IngestClass::Interactive)
            .unwrap();
        assert_eq!(late.wait().unwrap_err(), ServeError::Closed);
        assert!(report.records.iter().all(|r| r.checksum.is_finite()));
    })
}

#[test]
fn chaos_through_the_ingest_front_end_resolves_every_ticket_typed() {
    with_timeout("ingest chaos", || {
        let mix = chaos_mix();
        // Problem 0: recovers after one retry.  Problem 1: exhausts the
        // ladder and must surface its typed error on the ticket.
        let chaotic: Vec<Problem> = mix
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let kernel = match i {
                    0 => ChaosKernel::wrap(
                        p.kernel().clone(),
                        Some(FaultKind::Panic { worker: 0 }),
                    ),
                    1 => ChaosKernel::wrap(
                        ChaosKernel::wrap(p.kernel().clone(), Some(FaultKind::Poison)),
                        Some(FaultKind::Poison),
                    ),
                    _ => p.kernel().clone(),
                };
                Problem::from_kernel(kernel)
            })
            .collect();
        let server = IngestServer::start(
            Arc::new(engine(ScheduleKind::ThreadMapped, 2)),
            IngestConfig::builder()
                .max_batch(4)
                .max_wait(Duration::from_millis(2))
                .build()
                .unwrap(),
        );
        let handle = server.handle();
        let tickets: Vec<_> = chaotic
            .iter()
            .map(|p| handle.submit(p.clone(), IngestClass::Standard).unwrap())
            .collect();
        drop(handle);
        let verdicts: Vec<_> = tickets.into_iter().map(Ticket::wait).collect();
        assert!(verdicts[0].is_ok(), "{:?}", verdicts[0]);
        assert_eq!(
            verdicts[1].unwrap_err(),
            ServeError::Poisoned { retries: 1 }
        );
        assert!(verdicts[2].is_ok(), "{:?}", verdicts[2]);
        let report = server.finish().unwrap();
        assert_eq!(report.requests, 3);
        assert_eq!(report.faults.panics, 1);
        assert_eq!(report.faults.poisons, 1);
        assert_eq!(report.faults.failed, 1);
        assert_eq!(report.faults.recovered, 1);
    })
}

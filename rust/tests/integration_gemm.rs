//! Integration: full Chapter-5 pipeline — Stream-K plan executed through
//! the PJRT MacLoop artifacts, compared against the host reference GEMM.
//! Uses the artifact blocking geometries (128x128x32 f32, 64x64x16 f64).

use gpulb::exec::dense::DenseMat;
use gpulb::exec::gemm;
use gpulb::runtime::Runtime;
use gpulb::sim::gpu::Precision;
use gpulb::streamk::{decomp, Blocking, Decomposition, GemmShape};

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping (no artifacts): {e:#}");
            None
        }
    }
}

#[test]
fn streamk_f64_through_pjrt_exact() {
    let Some(rt) = runtime() else { return };
    // f64 artifacts: 64x64x16 blocking.  2x2 tiles, 4 iters/tile.
    let shape = GemmShape::new(128, 128, 64);
    let blk = Blocking::new(64, 64, 16);
    let a = DenseMat::random(shape.m, shape.k, 11);
    let b = DenseMat::random(shape.k, shape.n, 12);
    let want = DenseMat::matmul_ref(&a, &b);
    for d in [
        Decomposition::DataParallel,
        Decomposition::StreamK { g: 3 },
        Decomposition::FixedSplit { s: 2 },
    ] {
        let plan = decomp::plan(shape, blk, d);
        let got = gemm::execute_plan_runtime(&a, &b, &plan, &rt, Precision::F64).unwrap();
        let err = got.max_abs_diff(&want);
        assert!(err < 1e-10, "{d:?}: err {err}");
    }
}

#[test]
fn streamk_f32_through_pjrt_with_slabs() {
    let Some(rt) = runtime() else { return };
    // f32 artifacts: 128x128x32 blocking; k=512 => 16 iters/tile, so the
    // slab8 fused path gets exercised (16 = 2 slabs).
    let shape = GemmShape::new(128, 256, 512);
    let blk = Blocking::new(128, 128, 32);
    let a = DenseMat::random(shape.m, shape.k, 21);
    let b = DenseMat::random(shape.k, shape.n, 22);
    let want = DenseMat::matmul_ref(&a, &b);
    let plan = decomp::plan(shape, blk, Decomposition::StreamK { g: 5 });
    let got = gemm::execute_plan_runtime(&a, &b, &plan, &rt, Precision::F16F32).unwrap();
    // f32 accumulation over k=512 with inputs in [-1,1]: tolerance ~1e-3.
    let err = got.max_abs_diff(&want);
    assert!(err < 5e-3, "err {err}");
}

#[test]
fn ragged_shape_through_pjrt() {
    let Some(rt) = runtime() else { return };
    // Not divisible by the blocking: windows zero-pad, output clips.
    let shape = GemmShape::new(100, 90, 40);
    let blk = Blocking::new(64, 64, 16);
    let a = DenseMat::random(shape.m, shape.k, 31);
    let b = DenseMat::random(shape.k, shape.n, 32);
    let want = DenseMat::matmul_ref(&a, &b);
    let plan = decomp::plan(shape, blk, Decomposition::HybridTwoTile { p: 3 });
    let got = gemm::execute_plan_runtime(&a, &b, &plan, &rt, Precision::F64).unwrap();
    assert!(got.max_abs_diff(&want) < 1e-10);
}

#[test]
fn blocking_mismatch_is_rejected() {
    let Some(rt) = runtime() else { return };
    let shape = GemmShape::new(64, 64, 32);
    let blk = Blocking::new(32, 32, 8); // no artifact with this geometry
    let a = DenseMat::random(64, 32, 41);
    let b = DenseMat::random(32, 64, 42);
    let plan = decomp::plan(shape, blk, Decomposition::DataParallel);
    assert!(gemm::execute_plan_runtime(&a, &b, &plan, &rt, Precision::F64).is_err());
}

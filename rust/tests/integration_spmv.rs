//! Integration: full Chapter-4 pipeline — generate matrix, pick schedule,
//! build assignment, execute through the PJRT artifact path, compare with
//! the sequential reference.  Exercises sparse + balance + exec + runtime
//! together.

use gpulb::balance::{self, ScheduleKind};
use gpulb::exec::spmv;
use gpulb::runtime::Runtime;
use gpulb::sparse::gen;

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping (no artifacts): {e:#}");
            None
        }
    }
}

fn max_err(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[test]
fn spmv_through_pjrt_all_schedules() {
    let Some(rt) = runtime() else { return };
    let a = gen::power_law(600, 600, 300, 1.7, 97);
    let x: Vec<f64> = (0..a.cols).map(|i| ((i as f64) * 0.29).cos()).collect();
    let want = a.spmv_ref(&x);
    for kind in [
        ScheduleKind::ThreadMapped,
        ScheduleKind::GroupMapped(32),
        ScheduleKind::MergePath,
        ScheduleKind::NonzeroSplit,
        ScheduleKind::Binning,
        ScheduleKind::Lrb,
    ] {
        let asg = kind.assign(&a, 48);
        asg.validate(&a).unwrap();
        let y = spmv::execute_runtime(&a, &x, &asg, &rt).unwrap();
        let err = max_err(&y, &want);
        assert!(err < 1e-9, "{kind:?}: PJRT err {err}");
    }
}

#[test]
fn spmv_through_pjrt_heuristic_choice() {
    let Some(rt) = runtime() else { return };
    for (name, a) in [
        ("small-regular", gen::uniform(120, 120, 4, 5)),
        ("large-irregular", gen::power_law(2000, 2000, 900, 1.5, 6)),
        ("banded", gen::banded(512, 3, 7)),
    ] {
        let kind = balance::select_schedule(&a, balance::HeuristicParams::default());
        let asg = kind.assign(&a, 64);
        let x: Vec<f64> = (0..a.cols).map(|i| (i as f64 * 0.11).sin()).collect();
        let y = spmv::execute_runtime(&a, &x, &asg, &rt).unwrap();
        let err = max_err(&y, &a.spmv_ref(&x));
        assert!(err < 1e-9, "{name} via {kind:?}: err {err}");
    }
}

#[test]
fn spmv_pjrt_handles_empty_and_wide_rows() {
    let Some(rt) = runtime() else { return };
    // Matrix with empty rows and one row wider than the 32-lane slab.
    let mut coo = gpulb::sparse::Coo::new(8, 64);
    for c in 0..50 {
        coo.push(3, c, (c + 1) as f64 * 0.5);
    }
    coo.push(7, 0, 2.0);
    let a = gpulb::sparse::Csr::from_coo(&coo);
    let x: Vec<f64> = (0..64).map(|i| 1.0 + i as f64 * 0.01).collect();
    let want = a.spmv_ref(&x);
    let asg = ScheduleKind::MergePath.assign(&a, 4);
    let y = spmv::execute_runtime(&a, &x, &asg, &rt).unwrap();
    assert!(max_err(&y, &want) < 1e-12);
    assert_eq!(y[0], 0.0);
}

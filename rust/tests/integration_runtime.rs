//! Integration: the PJRT runtime loads every AOT artifact and produces
//! numerics matching known values — the same round trip the coordinators
//! take on the request path.
//!
//! Requires `make artifacts` (skips gracefully if absent, but CI always
//! builds them first).

use gpulb::runtime::{HostTensor, Runtime};

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime integration test (no artifacts): {e:#}");
            None
        }
    }
}

#[test]
fn manifest_contains_all_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in [
        "gemm_mac_iter_f32",
        "gemm_mac_slab8_f32",
        "tile_add_f32",
        "gemm_mac_iter_f64",
        "gemm_mac_slab8_f64",
        "tile_add_f64",
        "spmv_rowblock_f32",
        "spmv_rowblock_f64",
        "dot_chunk_f32",
        "dot_chunk_f64",
        "saxpy_f32",
    ] {
        assert!(rt.manifest().get(name).is_some(), "missing {name}");
    }
}

#[test]
fn gemm_mac_iter_known_values() {
    let Some(rt) = runtime() else { return };
    // ones(128,32) @ ones(32,128) + zeros = 32 everywhere.
    let a = HostTensor::F32(vec![1.0; 128 * 32], vec![128, 32]);
    let b = HostTensor::F32(vec![1.0; 32 * 128], vec![32, 128]);
    let acc = HostTensor::F32(vec![0.0; 128 * 128], vec![128, 128]);
    let out = rt.execute("gemm_mac_iter_f32", &[a, b, acc]).unwrap();
    let v = out.as_f32().unwrap();
    assert_eq!(v.len(), 128 * 128);
    assert!(v.iter().all(|&x| x == 32.0), "got {:?}...", &v[..4]);
}

#[test]
fn gemm_mac_iter_f64_accumulates() {
    let Some(rt) = runtime() else { return };
    let a = HostTensor::F64(vec![1.0; 64 * 16], vec![64, 16]);
    let b = HostTensor::F64(vec![2.0; 16 * 64], vec![16, 64]);
    let acc = HostTensor::F64(vec![5.0; 64 * 64], vec![64, 64]);
    let out = rt.execute("gemm_mac_iter_f64", &[a, b, acc]).unwrap();
    let v = out.as_f64().unwrap();
    assert!(v.iter().all(|&x| x == 16.0 * 2.0 + 5.0));
}

#[test]
fn slab8_equals_eight_single_iters() {
    let Some(rt) = runtime() else { return };
    let mut rng = gpulb::rng::Rng::new(1);
    let a: Vec<f32> = (0..128 * 256)
        .map(|_| rng.range_f64(-1.0, 1.0) as f32)
        .collect();
    let b: Vec<f32> = (0..256 * 128)
        .map(|_| rng.range_f64(-1.0, 1.0) as f32)
        .collect();
    let slab = rt
        .execute(
            "gemm_mac_slab8_f32",
            &[
                HostTensor::F32(a.clone(), vec![128, 256]),
                HostTensor::F32(b.clone(), vec![256, 128]),
                HostTensor::F32(vec![0.0; 128 * 128], vec![128, 128]),
            ],
        )
        .unwrap();

    // Iterate the single-step kernel 8 times over 32-wide K slices.
    let mut acc = HostTensor::F32(vec![0.0; 128 * 128], vec![128, 128]);
    for i in 0..8 {
        let a_blk: Vec<f32> = (0..128)
            .flat_map(|r| (0..32).map(move |c| (r, c)))
            .map(|(r, c)| a[r * 256 + i * 32 + c])
            .collect();
        let b_blk: Vec<f32> = (0..32)
            .flat_map(|r| (0..128).map(move |c| (r, c)))
            .map(|(r, c)| b[(i * 32 + r) * 128 + c])
            .collect();
        acc = rt
            .execute(
                "gemm_mac_iter_f32",
                &[
                    HostTensor::F32(a_blk, vec![128, 32]),
                    HostTensor::F32(b_blk, vec![32, 128]),
                    acc,
                ],
            )
            .unwrap();
    }
    let s = slab.as_f32().unwrap();
    let t = acc.as_f32().unwrap();
    let max_diff = s
        .iter()
        .zip(t)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "slab vs iterated diff {max_diff}");
}

#[test]
fn spmv_rowblock_matches_host_math() {
    let Some(rt) = runtime() else { return };
    let mut rng = gpulb::rng::Rng::new(2);
    let v: Vec<f64> = (0..128 * 32).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let xg: Vec<f64> = (0..128 * 32).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let out = rt
        .execute(
            "spmv_rowblock_f64",
            &[
                HostTensor::F64(v.clone(), vec![128, 32]),
                HostTensor::F64(xg.clone(), vec![128, 32]),
            ],
        )
        .unwrap();
    let y = out.as_f64().unwrap();
    for r in 0..128 {
        let want: f64 = (0..32).map(|j| v[r * 32 + j] * xg[r * 32 + j]).sum();
        assert!((y[r] - want).abs() < 1e-12, "row {r}: {} vs {want}", y[r]);
    }
}

#[test]
fn tile_add_fixup_artifact() {
    let Some(rt) = runtime() else { return };
    let x = HostTensor::F32(vec![1.5; 128 * 128], vec![128, 128]);
    let y = HostTensor::F32(vec![2.25; 128 * 128], vec![128, 128]);
    let out = rt.execute("tile_add_f32", &[x, y]).unwrap();
    assert!(out.as_f32().unwrap().iter().all(|&v| v == 3.75));
}

#[test]
fn saxpy_scalar_input_roundtrip() {
    let Some(rt) = runtime() else { return };
    let alpha = HostTensor::F32(vec![2.0], vec![]);
    let x = HostTensor::F32(vec![1.0; 4096], vec![4096]);
    let y = HostTensor::F32(vec![3.0; 4096], vec![4096]);
    let out = rt.execute("saxpy_f32", &[alpha, x, y]).unwrap();
    assert!(out.as_f32().unwrap().iter().all(|&v| v == 5.0));
}

#[test]
fn shape_mismatch_rejected() {
    let Some(rt) = runtime() else { return };
    let bad = HostTensor::F32(vec![0.0; 4], vec![2, 2]);
    let err = rt.execute("gemm_mac_iter_f32", &[bad.clone(), bad.clone(), bad]);
    assert!(err.is_err());
}

#[test]
fn unknown_artifact_rejected() {
    let Some(rt) = runtime() else { return };
    assert!(rt.execute("nonexistent_kernel", &[]).is_err());
}

#[test]
fn executables_cached_across_calls() {
    let Some(rt) = runtime() else { return };
    let a = HostTensor::F32(vec![1.0; 128 * 32], vec![128, 32]);
    let b = HostTensor::F32(vec![1.0; 32 * 128], vec![32, 128]);
    let acc = HostTensor::F32(vec![0.0; 128 * 128], vec![128, 128]);
    for _ in 0..3 {
        rt.execute("gemm_mac_iter_f32", &[a.clone(), b.clone(), acc.clone()])
            .unwrap();
    }
    assert_eq!(rt.call_counts()["gemm_mac_iter_f32"], 3);
}

//! Graph analytics on the load-balancing framework (§4.4.3): BFS and SSSP
//! over an R-MAT graph, demonstrating that the *same* schedules built for
//! sparse linear algebra balance graph traversals — plus the §3.3.5
//! task-queue policies on the dynamic BFS workload.
//!
//! Run with: `cargo run --release --example graph_analytics [rmat_scale]`

use gpulb::balance::queue::{QueueParams, QueuePolicy};
use gpulb::balance::ScheduleKind;
use gpulb::exec::graph;
use gpulb::sparse::{gen, stats, Coo, Csr};

fn connected_rmat(scale: u32, edge_factor: usize, seed: u64) -> Csr {
    // Union an R-MAT graph with a ring so BFS reaches every vertex.
    let base = gen::rmat(scale, edge_factor, seed);
    let n = base.rows;
    let mut coo = Coo::new(n, n);
    for v in 0..n {
        coo.push(v, (v + 1) % n, 1.0);
    }
    for r in 0..n {
        let (cols, vals) = base.row(r);
        for (c, v) in cols.iter().zip(vals) {
            coo.push(r, *c as usize, v.abs().max(0.25));
        }
    }
    Csr::from_coo(&coo)
}

fn main() {
    let scale: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let g = connected_rmat(scale, 8, 2022);
    let s = stats::row_stats(&g);
    println!(
        "R-MAT graph: {} vertices, {} edges, degree mean {:.1} / max {} (cv {:.2})\n",
        g.rows,
        g.nnz(),
        s.mean,
        s.max,
        s.cv
    );

    // --- BFS with every schedule, validated against the reference -------
    let want = graph::bfs_ref(&g, 0);
    let reached = want.iter().filter(|&&d| d != u32::MAX).count();
    let max_depth = want.iter().filter(|&&d| d != u32::MAX).max().unwrap();
    println!("BFS from vertex 0: {reached} reached, max depth {max_depth}");
    for kind in [
        ScheduleKind::ThreadMapped,
        ScheduleKind::GroupMapped(32),
        ScheduleKind::MergePath,
        ScheduleKind::NonzeroSplit,
    ] {
        let t0 = std::time::Instant::now();
        let got = graph::bfs(&g, 0, kind, 256);
        let ok = got == want;
        println!(
            "  {:<14} {:>8.2?}  {}",
            kind.name(),
            t0.elapsed(),
            if ok { "matches reference" } else { "MISMATCH" }
        );
        assert!(ok);
    }

    // --- SSSP (Listing 4.5) ---------------------------------------------
    let dist_ref = graph::sssp_ref(&g, 0);
    let dist = graph::sssp(&g, 0, ScheduleKind::MergePath, 256);
    let err = dist
        .iter()
        .zip(&dist_ref)
        .filter(|(a, b)| a.is_finite() && b.is_finite())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    println!("\nSSSP from vertex 0: max|err| vs Dijkstra {err:.3e}");

    // --- Task-queue policies on the dynamic BFS workload (§3.3.5) -------
    println!("\nqueue-based BFS (Algorithm 5) across §3.3.5 policies, 80 workers:");
    println!(
        "  {:<22} {:>12} {:>8} {:>8} {:>10} {:>6}",
        "policy", "makespan_us", "pops", "steals", "donations", "util"
    );
    for policy in [
        QueuePolicy::StaticList,
        QueuePolicy::Centralized,
        QueuePolicy::ChunkedFetch { chunk: 32 },
        QueuePolicy::Stealing,
        QueuePolicy::Donation { capacity: 64 },
    ] {
        let r = graph::bfs_queue_sim(&g, 0, policy, 80, QueueParams::default());
        println!(
            "  {:<22} {:>12.1} {:>8} {:>8} {:>10} {:>5.0}%",
            format!("{policy:?}"),
            r.makespan * 1e6,
            r.pops,
            r.steals,
            r.donations,
            r.utilization() * 100.0
        );
    }
    println!("\ngraph_analytics OK");
}

//! Stream-K vs the ensembles (the Fig. 5.7–5.9 workload): sweep a sample
//! of the 32,824-shape corpus, comparing Stream-K's single kernel against
//! data-parallel, the cuBLAS-like heuristic ensemble, and the oracle.
//!
//! Run with: `cargo run --release --example streamk_gemm [samples]`

use gpulb::baselines::vendor_gemm;
use gpulb::corpus::gemm_shapes;
use gpulb::metrics;
use gpulb::report::figures;
use gpulb::sim::gpu::{GpuSpec, Precision};

fn main() {
    let samples: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1500);
    let gpu = GpuSpec::a100();
    let shapes = gemm_shapes::gemm_corpus_sample(samples);
    println!(
        "corpus sample: {} shapes of {}, testbed {}\n",
        shapes.len(),
        gemm_shapes::GEMM_CORPUS_SIZE,
        gpu.name
    );

    for prec in [Precision::F16F32, Precision::F64] {
        let peak = gpu.peak_tflops(prec);
        let mut vs_dp = Vec::new();
        let mut vs_cublas = Vec::new();
        let mut vs_oracle = Vec::new();
        let mut sk_util = Vec::new();
        let mut cb_util = Vec::new();
        for &shape in &shapes {
            let sk = figures::streamk_time(shape, &gpu, prec);
            let dp = vendor_gemm::member_time(
                shape,
                gpulb::streamk::Blocking::paper_default(prec),
                1,
                &gpu,
                prec,
            );
            let cb = vendor_gemm::cublas_like_time(shape, &gpu, prec);
            let or = vendor_gemm::oracle_time(shape, &gpu, prec);
            vs_dp.push(dp / sk);
            vs_cublas.push(cb / sk);
            vs_oracle.push(or / sk);
            sk_util.push(shape.flops() / sk / 1e12 / peak);
            cb_util.push(shape.flops() / cb / 1e12 / peak);
        }
        println!("== {} ==", prec.name());
        for (name, xs) in [
            ("vs data-parallel", &vs_dp),
            ("vs cuBLAS-like", &vs_cublas),
            ("vs oracle", &vs_oracle),
        ] {
            let s = metrics::speedup_summary(xs);
            println!(
                "  {:<18} geomean {:.2}x  peak {:>6.2}x  min {:.2}x  >=1 on {:.0}%",
                name,
                s.geomean,
                s.peak,
                s.min,
                s.frac_at_least_one * 100.0
            );
        }
        println!(
            "  utilization       stream-k mean {:.2} (p5 {:.2}) | cuBLAS-like mean {:.2} (p5 {:.2})",
            metrics::mean(&sk_util),
            metrics::percentile(&sk_util, 5.0),
            metrics::mean(&cb_util),
            metrics::percentile(&cb_util, 5.0),
        );
        println!(
            "  consistency       stream-k p5/p95 spread {:.2} vs cuBLAS-like {:.2}\n",
            metrics::percentile(&sk_util, 95.0) - metrics::percentile(&sk_util, 5.0),
            metrics::percentile(&cb_util, 95.0) - metrics::percentile(&cb_util, 5.0),
        );
    }
    println!("paper reference: peak 14x vs DP, 6.7x vs cuBLAS, single kernel per precision");
}

//! End-to-end full-stack driver: proves all three layers compose on a real
//! workload, and records the paper's headline metrics.
//!
//! Pipeline per request (the production path):
//!   request -> L3 schedule decision (heuristic / grid-size model)
//!           -> balanced plan -> AOT Pallas kernel execution via PJRT
//!           -> numerics validation against the sequential reference
//!           -> modeled GPU time vs vendor baselines.
//!
//! Workload: a mixed queue of SpMV requests (graph + mesh + circuit
//! matrices) and GEMM requests (shapes from the Fig. 5.6 corpus),
//! processed by the coordinator loop.  Results land in EXPERIMENTS.md §E2E.
//!
//! Run with: `make artifacts && cargo run --release --example e2e_full_stack`

use std::time::Instant;

use gpulb::balance::{self};
use gpulb::baselines::{vendor_gemm, vendor_spmv};
use gpulb::corpus::gemm_shapes;
use gpulb::exec::{dense::DenseMat, gemm, spmv};
use gpulb::metrics;
use gpulb::report::figures;
use gpulb::runtime::Runtime;
use gpulb::sim::gpu::{GpuSpec, Precision};
use gpulb::sim::SpmvCost;
use gpulb::sparse::gen;
use gpulb::streamk::{decomp, Blocking, Decomposition};

fn main() -> gpulb::Result<()> {
    let t_start = Instant::now();
    let rt = Runtime::open_default()?;
    println!("PJRT platform: {}", rt.platform());
    rt.warmup(&[
        "spmv_rowblock_f64",
        "gemm_mac_iter_f64",
        "gemm_mac_slab8_f64",
    ])?;
    println!("artifacts warmed up in {:?}\n", t_start.elapsed());

    let v100 = GpuSpec::v100();
    let a100 = GpuSpec::a100();
    let spmv_cost = SpmvCost::calibrate(&v100);

    // ---------------- SpMV request stream -------------------------------
    let matrices = vec![
        ("powerlaw-2k", gen::power_law(2048, 2048, 1024, 1.6, 101)),
        ("powerlaw-4k", gen::power_law(4096, 4096, 2048, 1.9, 102)),
        ("uniform-2k", gen::uniform(2048, 2048, 16, 103)),
        ("banded-4k", gen::banded(4096, 4, 104)),
        ("blockdiag-2k", gen::block_diag(2048, 16, 105)),
        ("rmat-4k", gen::rmat(12, 8, 106)),
    ];

    println!("== SpMV requests (schedule heuristic -> PJRT execution) ==");
    println!(
        "  {:<14} {:>9} {:>14} {:>12} {:>11} {:>10}",
        "matrix", "nnz", "schedule", "max|err|", "latency", "speedup*"
    );
    let mut spmv_speedups = Vec::new();
    let mut spmv_latencies = Vec::new();
    let workers = v100.sms * spmv_cost.block_threads;
    for (name, a) in &matrices {
        let kind = balance::select_schedule(a, balance::HeuristicParams::default());
        let asg = kind.assign(a, workers);
        asg.validate(a)?;
        let x: Vec<f64> = (0..a.cols).map(|i| (i as f64 * 0.17).sin()).collect();

        let t0 = Instant::now();
        let y = spmv::execute_runtime(a, &x, &asg, &rt)?;
        let lat = t0.elapsed();
        spmv_latencies.push(lat.as_secs_f64());

        let want = a.spmv_ref(&x);
        let err = y
            .iter()
            .zip(&want)
            .map(|(g, w)| (g - w).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-9, "{name}: numerics diverged ({err})");

        let ours = spmv::modeled_time(a, &asg, Some(kind), &spmv_cost, &v100);
        let vendor = vendor_spmv::modeled_time(a, &spmv_cost, &v100);
        spmv_speedups.push(vendor / ours);
        println!(
            "  {:<14} {:>9} {:>14} {:>12.2e} {:>11.2?} {:>9.2}x",
            name,
            a.nnz(),
            kind.name(),
            err,
            lat,
            vendor / ours
        );
    }

    // ---------------- GEMM request stream -------------------------------
    println!("\n== GEMM requests (grid-size model -> Stream-K -> PJRT MacLoop) ==");
    println!(
        "  {:<16} {:>6} {:>6} {:>12} {:>11} {:>10} {:>10}",
        "shape", "tiles", "g", "max|err|", "latency", "vs DP*", "vs cuBLAS*"
    );
    let prec = Precision::F64;
    let blk = Blocking::paper_default(prec);
    let model = vendor_gemm::member_cost_model(&a100, blk, prec);
    // Small-but-real shapes (host-side verification is O(mnk)).
    let gemm_shapes = [
        (192usize, 192usize, 128usize),
        (256, 128, 256),
        (128, 320, 96),
        (384, 384, 64),
    ];
    let mut dp_speedups = Vec::new();
    let mut cb_speedups = Vec::new();
    let mut gemm_latencies = Vec::new();
    for &(m, n, k) in &gemm_shapes {
        let shape = gpulb::streamk::GemmShape::new(m, n, k);
        let g = gpulb::streamk::best_grid(shape, blk, a100.sms, &model);
        let plan = decomp::plan(shape, blk, Decomposition::StreamK { g });
        plan.validate()?;

        let am = DenseMat::random(m, k, m as u64);
        let bm = DenseMat::random(k, n, n as u64);
        let t0 = Instant::now();
        let got = gemm::execute_plan_runtime(&am, &bm, &plan, &rt, prec)?;
        let lat = t0.elapsed();
        gemm_latencies.push(lat.as_secs_f64());
        let err = got.max_abs_diff(&DenseMat::matmul_ref(&am, &bm));
        assert!(err < 1e-9, "{m}x{n}x{k}: numerics diverged ({err})");

        let sk = figures::streamk_time(shape, &a100, prec);
        let dp = vendor_gemm::member_time(shape, blk, 1, &a100, prec);
        let cb = vendor_gemm::cublas_like_time(shape, &a100, prec);
        dp_speedups.push(dp / sk);
        cb_speedups.push(cb / sk);
        println!(
            "  {:<16} {:>6} {:>6} {:>12.2e} {:>11.2?} {:>9.2}x {:>9.2}x",
            format!("{m}x{n}x{k}"),
            plan.num_tiles,
            g,
            err,
            lat,
            dp / sk,
            cb / sk
        );
    }

    // ---------------- headline summary ----------------------------------
    let calls: u64 = rt.call_counts().values().sum();
    let wall = t_start.elapsed();
    println!("\n== headline metrics (record in EXPERIMENTS.md §E2E) ==");
    println!(
        "  SpMV heuristic speedup vs cuSparse-like (modeled):  geomean {:.2}x  (paper: 2.7x)",
        metrics::geomean(&spmv_speedups)
    );
    println!(
        "  Stream-K speedup vs data-parallel (modeled):        geomean {:.2}x",
        metrics::geomean(&dp_speedups)
    );
    println!(
        "  Stream-K speedup vs cuBLAS-like (modeled):          geomean {:.2}x",
        metrics::geomean(&cb_speedups)
    );
    println!(
        "  request latencies (CPU PJRT): SpMV p50 {:.0} ms, GEMM p50 {:.0} ms",
        metrics::percentile(&spmv_latencies, 50.0) * 1e3,
        metrics::percentile(&gemm_latencies, 50.0) * 1e3
    );
    println!(
        "  {} requests, {} PJRT kernel invocations, wall {:.1?}",
        matrices.len() + gemm_shapes.len(),
        calls,
        wall
    );
    println!(
        "  corpus scale available: {} GEMM shapes",
        gemm_shapes::GEMM_CORPUS_SIZE
    );
    println!("\ne2e_full_stack OK — all layers compose with exact numerics");
    Ok(())
}

//! Quickstart: the 60-second tour of the library.
//!
//! 1. Generate an irregular sparse matrix.
//! 2. Let the §4.5.2 heuristic pick a load-balancing schedule.
//! 3. Execute SpMV through the AOT Pallas kernel via PJRT and check it
//!    against the sequential reference.
//! 4. Plan a Stream-K GEMM, execute it through the MacLoop artifact, and
//!    compare modeled time against the data-parallel baseline.
//!
//! Run with: `make artifacts && cargo run --example quickstart`

use gpulb::balance::{self, ScheduleKind};
use gpulb::baselines::vendor_gemm;
use gpulb::exec::{dense::DenseMat, gemm, spmv};
use gpulb::runtime::Runtime;
use gpulb::sim::gpu::{GpuSpec, Precision};
use gpulb::sim::SpmvCost;
use gpulb::sparse::gen;
use gpulb::streamk::{self, decomp, Blocking, Decomposition, GemmShape};

fn main() -> gpulb::Result<()> {
    let rt = Runtime::open_default()?;
    println!("PJRT platform: {}\n", rt.platform());

    // ---- Chapter 4: load-balanced SpMV --------------------------------
    println!("== SpMV through the load-balancing framework ==");
    let a = gen::power_law(2048, 2048, 1024, 1.7, 42);
    let kind = balance::select_schedule(&a, balance::HeuristicParams::default());
    println!(
        "matrix: {}x{}, nnz {}; heuristic picked `{}`",
        a.rows,
        a.cols,
        a.nnz(),
        kind.name()
    );

    let asg = kind.assign(&a, 80 * 128);
    asg.validate(&a)?;
    let x: Vec<f64> = (0..a.cols).map(|i| (i as f64 * 0.37).sin()).collect();
    let y = spmv::execute_runtime(&a, &x, &asg, &rt)?;
    let want = a.spmv_ref(&x);
    let err = y
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0, f64::max);
    println!("PJRT numerics max|err| vs reference: {err:.3e}");

    let gpu = GpuSpec::v100();
    let cost = SpmvCost::calibrate(&gpu);
    let ours = spmv::modeled_time(&a, &asg, Some(kind), &cost, &gpu);
    let vendor = gpulb::baselines::vendor_spmv::modeled_time(&a, &cost, &gpu);
    println!(
        "modeled: ours {:.1} us vs cuSparse-like {:.1} us  ({:.2}x)\n",
        ours * 1e6,
        vendor * 1e6,
        vendor / ours
    );

    // Swapping the schedule is a one-line change (the paper's key claim):
    for other in [ScheduleKind::ThreadMapped, ScheduleKind::MergePath] {
        let t = spmv::modeled_time(&a, &other.assign(&a, 80 * 128), Some(other), &cost, &gpu);
        println!("  schedule swap -> {:<14} {:.1} us", other.name(), t * 1e6);
    }

    // ---- Chapter 5: Stream-K GEMM -------------------------------------
    println!("\n== Stream-K GEMM through the PJRT MacLoop ==");
    let prec = Precision::F64;
    let blk = Blocking::paper_default(prec); // 64x64x16
    let shape = GemmShape::new(192, 192, 96);
    let gpu = GpuSpec::a100();
    let model = vendor_gemm::member_cost_model(&gpu, blk, prec);
    let g = streamk::best_grid(shape, blk, gpu.sms, &model);
    let plan = decomp::plan(shape, blk, Decomposition::StreamK { g });
    println!(
        "shape {}x{}x{}: {} tiles, grid-size model picked g={}",
        shape.m, shape.n, shape.k, plan.num_tiles, g
    );

    let am = DenseMat::random(shape.m, shape.k, 1);
    let bm = DenseMat::random(shape.k, shape.n, 2);
    let got = gemm::execute_plan_runtime(&am, &bm, &plan, &rt, prec)?;
    let err = got.max_abs_diff(&DenseMat::matmul_ref(&am, &bm));
    println!("PJRT numerics max|err|: {err:.3e}");

    let sk = gemm::simulate_plan(&plan, &model, &gpu, prec);
    let dp = vendor_gemm::member_time(shape, blk, 1, &gpu, prec);
    println!(
        "modeled: stream-k {:.1} us vs data-parallel {:.1} us  ({:.2}x)",
        sk.makespan * 1e6,
        dp * 1e6,
        dp / sk.makespan
    );
    println!("\nquickstart OK");
    Ok(())
}

//! SpMV performance landscape (the Fig. 4.3/4.4 workload): sweep every
//! framework schedule and the vendor baseline across the synthetic
//! SuiteSparse-substitute corpus, reporting per-family geomean speedups.
//!
//! Run with: `cargo run --release --example spmv_landscape [scale]`

use std::collections::BTreeMap;

use gpulb::balance::{self, ScheduleKind};
use gpulb::baselines::vendor_spmv;
use gpulb::corpus::sparse_corpus;
use gpulb::exec::spmv;
use gpulb::metrics;
use gpulb::sim::{GpuSpec, SpmvCost};

fn main() {
    let scale: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let gpu = GpuSpec::v100();
    let cost = SpmvCost::calibrate(&gpu);
    let corpus = sparse_corpus(scale);
    println!(
        "corpus: {} matrices (scale {scale}), testbed {}\n",
        corpus.len(),
        gpu.name
    );

    let kinds = [
        ScheduleKind::ThreadMapped,
        ScheduleKind::GroupMapped(32),
        ScheduleKind::MergePath,
        ScheduleKind::NonzeroSplit,
        ScheduleKind::Binning,
        ScheduleKind::Lrb,
    ];

    // family -> (per-schedule speedups vs vendor, heuristic speedups)
    let mut by_family: BTreeMap<&str, Vec<Vec<f64>>> = BTreeMap::new();
    let mut heuristic: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    let workers = gpu.sms * cost.block_threads;

    for e in &corpus {
        let vendor = vendor_spmv::modeled_time(&e.matrix, &cost, &gpu);
        let fam = by_family
            .entry(e.family)
            .or_insert_with(|| vec![Vec::new(); kinds.len()]);
        for (i, &kind) in kinds.iter().enumerate() {
            let t = spmv::modeled_time(
                &e.matrix,
                &kind.assign(&e.matrix, workers),
                Some(kind),
                &cost,
                &gpu,
            );
            fam[i].push(vendor / t);
        }
        let hk = balance::select_schedule(&e.matrix, balance::HeuristicParams::default());
        let ht = spmv::modeled_time(
            &e.matrix,
            &hk.assign(&e.matrix, workers),
            Some(hk),
            &cost,
            &gpu,
        );
        heuristic.entry(e.family).or_default().push(vendor / ht);
    }

    println!(
        "{:<16} {:>14} {:>14} {:>14} {:>14} {:>14} {:>14} {:>12}",
        "family (geomean speedup vs cuSparse-like)",
        "thread",
        "warp",
        "merge",
        "nzsplit",
        "binning",
        "lrb",
        "heuristic"
    );
    let mut all_heur = Vec::new();
    for (fam, per_kind) in &by_family {
        let h = &heuristic[fam];
        all_heur.extend_from_slice(h);
        print!("{fam:<42}");
        for xs in per_kind {
            print!(" {:>13.2}x", metrics::geomean(xs));
        }
        println!(" {:>11.2}x", metrics::geomean(h));
    }
    let s = metrics::speedup_summary(&all_heur);
    println!(
        "\nheuristic overall: geomean {:.2}x, peak {:.1}x, min {:.2}x (paper: 2.7x geomean, 39x peak)",
        s.geomean, s.peak, s.min
    );
}
